//! Explicit vector lanes for the hot tile primitives, std-only.
//!
//! The GEMM-shaped base case (`compute::tile` + `compute::fastexp`)
//! auto-vectorizes on a good day, but the portable build cannot assume
//! AVX2/FMA or NEON at compile time, so the hottest loops — the dot
//! tile, the fused norms-trick + certified exp pass, and the weighted
//! reduction — are duplicated here as `core::arch` kernels and selected
//! **once per process** by runtime feature detection behind a
//! function-pointer table ([`Lanes`]).
//!
//! Three backends:
//!
//! * **scalar** — delegates verbatim to [`microkernel`] / [`fastexp`].
//!   This is the bit-exact-vs-today reference: with SIMD forced off
//!   (`SimdMode::Off`, or the process-wide `FASTGAUSS_SIMD=off`
//!   environment override read at first detection) every deterministic
//!   engine produces bit-identical sums to the pre-SIMD scalar path.
//! * **avx2** (x86_64, requires AVX2+FMA at runtime) — 4×f64 / 8×f32
//!   lanes, FMA chains, and a lane-wide [`fastexp`]: the same
//!   `LN2_HI`/`LN2_LO` Cody–Waite reduction and degree-11 Horner
//!   polynomial, with `2^k` assembled in the exponent field via
//!   `_mm256_slli_epi64` and the underflow tail handled by a per-lane
//!   blend instead of a branch.
//! * **neon** (aarch64) — the same algorithm on 2×f64 / 4×f32 lanes.
//!
//! # Why the vector kernels stay inside the certificate
//!
//! The dot tile keeps the exact per-element contract (`tile[t,j] =
//! Σ_k q_k·r_kj` accumulated dims-ascending); fusing the
//! multiply-accumulate only *removes* intermediate roundings, so the
//! `errorcontrol::base_case_rel_err` cancellation bound (derived for
//! one rounding per operation) still holds. The vector exp mirrors the
//! scalar algorithm constant-for-constant; FMA in the Horner recurrence
//! and in the range reduction again only tightens the 2.0e-14 budget
//! certified as [`fastexp::EXP_MAX_REL_ERR`] = 1e-13 (ties in
//! `round(x/ln2)` may break to even instead of away from zero, which
//! moves `r` across the seam but keeps `|r| ≤ ln(2)/2 + 1 ulp`, the
//! only property the budget uses). The weighted reduction is the one
//! primitive whose *order* changes (lane-strided partial sums folded at
//! the end); for the non-negative terms `w_j·K̃ ≥ 0` any summation
//! order is within `(n−1)·u · Σ w_j·K̃` of any other — the same class
//! and magnitude of error the sequential sum already carries in every
//! path including the exhaustive truth, absorbed by the existing
//! `base_case_rel_err` slack (see DESIGN.md §"Vector lanes").
//!
//! The f32 lane variants ([`Lanes::dot_tile_f32`]) are *not* silently
//! substituted: the mixed-precision tile is a separate driver
//! (`tile::gauss_sums_fast_f32_on_loaded`) that only runs when
//! `errorcontrol::split_epsilon_prec` has charged the derived f32
//! representation error against the ε budget.

// lint: allow(sync-bypass): process-wide one-time lane detection below the runtime layer — no scheduling to explore
use std::sync::OnceLock;

use super::fastexp;
use super::microkernel;

/// SIMD dispatch policy, selectable per session/config (`simd=` key,
/// `--simd`); `FASTGAUSS_SIMD=off` in the environment pins the whole
/// process to scalar regardless (CI runs one such leg).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Runtime feature detection: AVX2+FMA → avx2, aarch64 → neon,
    /// otherwise (or under `FASTGAUSS_SIMD=off`) scalar.
    #[default]
    Auto,
    /// Force the portable scalar kernels — bit-identical to the
    /// pre-SIMD code path; the determinism-pinning override.
    Off,
}

impl SimdMode {
    /// Accepted spellings for config/CLI parsing.
    pub const VALID: &'static str = "auto, off";

    pub fn parse(s: &str) -> Option<SimdMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(SimdMode::Auto),
            "off" | "scalar" => Some(SimdMode::Off),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Off => "off",
        }
    }
}

/// Base-case arithmetic precision, selectable per session/config
/// (`precision=` key, `--precision`).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f64 pipeline (default).
    #[default]
    F64,
    /// Mixed precision: f32 reference lanes/norms/weights and f32 dot
    /// tile, f64 exponent + accumulators. Only *taken* when
    /// `errorcontrol::split_epsilon_prec` can afford the derived f32
    /// bound inside ε/4; otherwise the evaluate silently falls back to
    /// the certified f64 fast path (or bit-exact), staying ε-sound.
    F32,
}

impl Precision {
    /// Accepted spellings for config/CLI parsing.
    pub const VALID: &'static str = "f64, f32";

    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// Which kernel set a [`Lanes`] table points at.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

type ExpBlockFn = fn(&mut [f64]);
type DotSoaFn = fn(&[f64], &[f64], usize, usize, &mut [f64]);
type DotTileFn = fn(&[f64], usize, usize, &[f64], usize, usize, usize, &mut [f64]);
type WeightedSumFn = fn(&[f64], &[f64]) -> f64;
/// `(neg, qnorm, rnorm, vals, n)`: fused
/// `vals[j] ← exp((qnorm + rnorm[j] − 2·vals[j]).max(0)·neg)`.
type GaussFromNormsFn = fn(f64, f64, &[f64], &mut [f64], usize);
type DotTileF32Fn = fn(&[f32], usize, usize, &[f32], usize, usize, usize, &mut [f32]);

/// The per-process kernel table. Obtained from [`active`] /
/// [`select`]; all entries of one table belong to the same backend, so
/// a fixed table is deterministic across calls, threads and pool
/// widths.
pub struct Lanes {
    pub backend: Backend,
    /// Certified block exp (`fastexp` contract, same bound).
    pub exp_block: ExpBlockFn,
    /// Single-query SoA dot products (`microkernel::dot_soa` contract).
    pub dot_soa: DotSoaFn,
    /// Query-tile × reference-lane dot tile (`microkernel::dot_tile`).
    pub dot_tile: DotTileFn,
    /// Weighted reduction `Σ w_j·v_j` over non-negative terms.
    pub weighted_sum: WeightedSumFn,
    /// Fused norms-trick + certified exp row pass.
    pub gauss_from_norms: GaussFromNormsFn,
    /// f32-lane dot tile for the mixed-precision base case.
    pub dot_tile_f32: DotTileF32Fn,
}

// ---------------------------------------------------------------------------
// scalar backend — delegates to the existing portable code, verbatim
// ---------------------------------------------------------------------------

/// The scalar norms-trick fusion; `tile::gauss_from_norms_into` is a
/// thin wrapper so there is exactly one bit-exact reference body.
pub(crate) fn gauss_from_norms_scalar(
    neg: f64,
    qnorm: f64,
    rnorm: &[f64],
    vals: &mut [f64],
    n: usize,
) {
    let (vals, rnorm) = (&mut vals[..n], &rnorm[..n]);
    for j in 0..n {
        vals[j] = (qnorm + rnorm[j] - 2.0 * vals[j]).max(0.0) * neg;
    }
    fastexp::exp_block(vals);
}

/// f32 mirror of `microkernel::dot_tile`: same zero-fill + dims-outer
/// multiply-accumulate loop nest, f32 arithmetic.
fn dot_tile_f32_scalar(
    qsoa: &[f32],
    qstride: usize,
    nq: usize,
    rsoa: &[f32],
    rstride: usize,
    n: usize,
    dims: usize,
    tile: &mut [f32],
) {
    debug_assert!(nq <= qstride && dims * qstride <= qsoa.len());
    debug_assert!(n <= rstride && nq * rstride <= tile.len());
    for t in 0..nq {
        tile[t * rstride..t * rstride + n].fill(0.0);
    }
    for k in 0..dims {
        let lane = &rsoa[k * rstride..k * rstride + n];
        for t in 0..nq {
            let qv = qsoa[k * qstride + t];
            let row = &mut tile[t * rstride..t * rstride + n];
            for j in 0..n {
                row[j] += qv * lane[j];
            }
        }
    }
}

static SCALAR: Lanes = Lanes {
    backend: Backend::Scalar,
    exp_block: fastexp::exp_block,
    dot_soa: microkernel::dot_soa,
    dot_tile: microkernel::dot_tile,
    weighted_sum: microkernel::weighted_sum,
    gauss_from_norms: gauss_from_norms_scalar,
    dot_tile_f32: dot_tile_f32_scalar,
};

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

/// The portable scalar table — the bit-exact reference backend.
pub fn scalar() -> &'static Lanes {
    &SCALAR
}

/// How a `FASTGAUSS_SIMD` value classifies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnvSimd {
    /// `off` / `scalar` / `0`: pin the scalar table.
    ForceOff,
    /// Unset, empty, or `auto` / `on` / `1`: use CPU detection.
    Auto,
    /// Anything else: warn once, then behave like [`EnvSimd::Auto`].
    Unrecognized,
}

/// Classify a `FASTGAUSS_SIMD` value without touching the process
/// environment (`None` = the variable is unset). Matching is
/// case-insensitive and whitespace-tolerant.
pub fn parse_env_simd(value: Option<&str>) -> EnvSimd {
    match value {
        None => EnvSimd::Auto,
        Some(v) => match v.to_ascii_lowercase().trim() {
            "off" | "scalar" | "0" => EnvSimd::ForceOff,
            "" | "auto" | "on" | "1" => EnvSimd::Auto,
            _ => EnvSimd::Unrecognized,
        },
    }
}

/// The process-wide auto-detected table, resolved once: honours
/// `FASTGAUSS_SIMD=off|scalar|0` first, then runtime CPU features. An
/// unrecognized value warns once on stderr and falls back to
/// detection instead of being silently treated as `off`.
pub fn active() -> &'static Lanes {
    // lint: allow(sync-bypass): process-wide one-time lane detection below the runtime layer — no scheduling to explore
    static ACTIVE: OnceLock<&'static Lanes> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let raw = std::env::var("FASTGAUSS_SIMD").ok();
        match parse_env_simd(raw.as_deref()) {
            EnvSimd::ForceOff => &SCALAR,
            EnvSimd::Auto => detect(),
            EnvSimd::Unrecognized => {
                // the OnceLock init runs once, so this warns once
                let v = raw.unwrap_or_default();
                eprintln!(
                    "fastgauss: FASTGAUSS_SIMD={v:?} is not recognized \
                     (expected off|scalar|0 or auto|on|1); using auto-detection"
                );
                detect()
            }
        }
    })
}

/// Resolve a [`SimdMode`] to its kernel table.
pub fn select(mode: SimdMode) -> &'static Lanes {
    match mode {
        SimdMode::Auto => active(),
        SimdMode::Off => &SCALAR,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> &'static Lanes {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        &AVX2
    } else {
        &SCALAR
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> &'static Lanes {
    if std::arch::is_aarch64_feature_detected!("neon") {
        &NEON
    } else {
        &SCALAR
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> &'static Lanes {
    &SCALAR
}

// ---------------------------------------------------------------------------
// avx2 backend (x86_64, runtime AVX2+FMA)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2: Lanes = Lanes {
    backend: Backend::Avx2,
    exp_block: avx2::exp_block,
    dot_soa: avx2::dot_soa,
    dot_tile: avx2::dot_tile,
    weighted_sum: avx2::weighted_sum,
    gauss_from_norms: avx2::gauss_from_norms,
    dot_tile_f32: avx2::dot_tile_f32,
};

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! 4×f64 / 8×f32 kernels. Every public entry here is a *safe* fn
    //! wrapper (so it coerces to the [`super::Lanes`] pointers) around
    //! a `#[target_feature(enable = "avx2,fma")]` body; the wrappers
    //! are only ever installed in the table after
    //! `is_x86_feature_detected!` confirmed both features, which is
    //! what makes the inner `unsafe` calls sound.

    use std::arch::x86_64::*;

    use crate::compute::fastexp;
    use crate::compute::fastexp::{C, EXP_UNDERFLOW_X, INV_LN2, LN2_HI, LN2_LO};

    /// One lane-wide certified exp: the scalar [`fastexp::fast_exp`]
    /// algorithm verbatim — Cody–Waite reduction with the same
    /// `LN2_HI`/`LN2_LO` split, degree-11 Horner on fused lanes, `2^k`
    /// assembled in the exponent field, per-lane underflow blend.
    // SAFETY: register-only arithmetic, no memory access; the caller
    // must hold the avx2+fma witness (every caller is an `_impl` in
    // this module, reached only through wrappers installed after
    // runtime detection).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp4(x: __m256d) -> __m256d {
        // k = round(x / ln 2); rounding mode 0b00 (nearest) + NO_EXC.
        let k = _mm256_round_pd::<0b1000>(_mm256_mul_pd(x, _mm256_set1_pd(INV_LN2)));
        // r = (x − k·LN2_HI) − k·LN2_LO (fnmadd keeps k·LN2_HI exact —
        // the product is exact by the Cody–Waite construction, so the
        // fused form equals the scalar two-op form bit for bit).
        let r = _mm256_fnmadd_pd(k, _mm256_set1_pd(LN2_HI), x);
        let r = _mm256_fnmadd_pd(k, _mm256_set1_pd(LN2_LO), r);
        let mut p = _mm256_set1_pd(C[11]);
        let mut j = 11;
        while j > 0 {
            j -= 1;
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(C[j]));
        }
        // 2^k via the exponent bits. k is integral and, on the
        // certified domain [−708, 709], within [−1022, 1023], so the
        // biased exponent lands in [1, 2046] (a normal f64). Outside
        // the domain the bits may wrap — exactly the lanes the
        // underflow blend below zeroes (x < −708) or that the
        // contract leaves unspecified (x > 709).
        let ki = _mm256_cvtpd_epi32(k);
        let k64 = _mm256_cvtepi32_epi64(ki);
        let biased = _mm256_add_epi64(k64, _mm256_set1_epi64x(1023));
        let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(biased));
        let v = _mm256_mul_pd(p, scale);
        let keep = _mm256_cmp_pd::<_CMP_GE_OQ>(x, _mm256_set1_pd(EXP_UNDERFLOW_X));
        _mm256_and_pd(v, keep)
    }

    // SAFETY: caller must hold the avx2+fma witness (the safe wrapper
    // below is installed only after runtime detection); every
    // load/store stays inside `xs` — the vector loop requires
    // `j + 4 <= n` and the tail is scalar.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp_block_impl(xs: &mut [f64]) {
        let n = xs.len();
        let ptr = xs.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = _mm256_loadu_pd(ptr.add(j));
            _mm256_storeu_pd(ptr.add(j), exp4(v));
            j += 4;
        }
        while j < n {
            xs[j] = fastexp::fast_exp(xs[j]);
            j += 1;
        }
    }

    pub(super) fn exp_block(xs: &mut [f64]) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { exp_block_impl(xs) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_soa_impl(q: &[f64], soa: &[f64], stride: usize, n: usize, out: &mut [f64]) {
        let out = &mut out[..n];
        out.fill(0.0);
        for (k, &qk) in q.iter().enumerate() {
            let lane = &soa[k * stride..k * stride + n];
            let qv = _mm256_set1_pd(qk);
            let mut j = 0;
            while j + 4 <= n {
                let l = _mm256_loadu_pd(lane.as_ptr().add(j));
                let o = _mm256_loadu_pd(out.as_ptr().add(j));
                _mm256_storeu_pd(out.as_mut_ptr().add(j), _mm256_fmadd_pd(qv, l, o));
                j += 4;
            }
            while j < n {
                out[j] += qk * lane[j];
                j += 1;
            }
        }
    }

    pub(super) fn dot_soa(q: &[f64], soa: &[f64], stride: usize, n: usize, out: &mut [f64]) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { dot_soa_impl(q, soa, stride, n, out) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_tile_impl(
        qsoa: &[f64],
        qstride: usize,
        nq: usize,
        rsoa: &[f64],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f64],
    ) {
        debug_assert!(nq <= qstride && dims * qstride <= qsoa.len());
        debug_assert!(n <= rstride && nq * rstride <= tile.len());
        for t in 0..nq {
            tile[t * rstride..t * rstride + n].fill(0.0);
        }
        for k in 0..dims {
            let lane = &rsoa[k * rstride..k * rstride + n];
            for t in 0..nq {
                let qk = qsoa[k * qstride + t];
                let qv = _mm256_set1_pd(qk);
                let row = &mut tile[t * rstride..t * rstride + n];
                let mut j = 0;
                while j + 4 <= n {
                    let l = _mm256_loadu_pd(lane.as_ptr().add(j));
                    let o = _mm256_loadu_pd(row.as_ptr().add(j));
                    _mm256_storeu_pd(row.as_mut_ptr().add(j), _mm256_fmadd_pd(qv, l, o));
                    j += 4;
                }
                while j < n {
                    row[j] += qk * lane[j];
                    j += 1;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn dot_tile(
        qsoa: &[f64],
        qstride: usize,
        nq: usize,
        rsoa: &[f64],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f64],
    ) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { dot_tile_impl(qsoa, qstride, nq, rsoa, rstride, n, dims, tile) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn weighted_sum_impl(w: &[f64], v: &[f64]) -> f64 {
        debug_assert_eq!(w.len(), v.len());
        let n = w.len();
        let mut acc = _mm256_setzero_pd();
        let mut j = 0;
        while j + 4 <= n {
            let wv = _mm256_loadu_pd(w.as_ptr().add(j));
            let vv = _mm256_loadu_pd(v.as_ptr().add(j));
            acc = _mm256_fmadd_pd(wv, vv, acc);
            j += 4;
        }
        // fixed fold order keeps the reduction deterministic
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        while j < n {
            s += w[j] * v[j];
            j += 1;
        }
        s
    }

    pub(super) fn weighted_sum(w: &[f64], v: &[f64]) -> f64 {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { weighted_sum_impl(w, v) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn gauss_from_norms_impl(
        neg: f64,
        qnorm: f64,
        rnorm: &[f64],
        vals: &mut [f64],
        n: usize,
    ) {
        let (vals, rnorm) = (&mut vals[..n], &rnorm[..n]);
        let qn = _mm256_set1_pd(qnorm);
        let negv = _mm256_set1_pd(neg);
        let two = _mm256_set1_pd(2.0);
        let zero = _mm256_setzero_pd();
        let mut j = 0;
        while j + 4 <= n {
            let rn = _mm256_loadu_pd(rnorm.as_ptr().add(j));
            let v = _mm256_loadu_pd(vals.as_ptr().add(j));
            // (qn + rn) − 2·v: 2·v is exact, so the fused form matches
            // the scalar `qnorm + rnorm[j] - 2.0*vals[j]` bit for bit.
            let sq = _mm256_fnmadd_pd(two, v, _mm256_add_pd(qn, rn));
            let x = _mm256_mul_pd(_mm256_max_pd(sq, zero), negv);
            _mm256_storeu_pd(vals.as_mut_ptr().add(j), exp4(x));
            j += 4;
        }
        while j < n {
            vals[j] = fastexp::fast_exp((qnorm + rnorm[j] - 2.0 * vals[j]).max(0.0) * neg);
            j += 1;
        }
    }

    pub(super) fn gauss_from_norms(
        neg: f64,
        qnorm: f64,
        rnorm: &[f64],
        vals: &mut [f64],
        n: usize,
    ) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { gauss_from_norms_impl(neg, qnorm, rnorm, vals, n) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_tile_f32_impl(
        qsoa: &[f32],
        qstride: usize,
        nq: usize,
        rsoa: &[f32],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f32],
    ) {
        debug_assert!(nq <= qstride && dims * qstride <= qsoa.len());
        debug_assert!(n <= rstride && nq * rstride <= tile.len());
        for t in 0..nq {
            tile[t * rstride..t * rstride + n].fill(0.0);
        }
        for k in 0..dims {
            let lane = &rsoa[k * rstride..k * rstride + n];
            for t in 0..nq {
                let qk = qsoa[k * qstride + t];
                let qv = _mm256_set1_ps(qk);
                let row = &mut tile[t * rstride..t * rstride + n];
                let mut j = 0;
                while j + 8 <= n {
                    let l = _mm256_loadu_ps(lane.as_ptr().add(j));
                    let o = _mm256_loadu_ps(row.as_ptr().add(j));
                    _mm256_storeu_ps(row.as_mut_ptr().add(j), _mm256_fmadd_ps(qv, l, o));
                    j += 8;
                }
                while j < n {
                    row[j] += qk * lane[j];
                    j += 1;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn dot_tile_f32(
        qsoa: &[f32],
        qstride: usize,
        nq: usize,
        rsoa: &[f32],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f32],
    ) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { dot_tile_f32_impl(qsoa, qstride, nq, rsoa, rstride, n, dims, tile) }
    }
}

// ---------------------------------------------------------------------------
// neon backend (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON: Lanes = Lanes {
    backend: Backend::Neon,
    exp_block: neon::exp_block,
    dot_soa: neon::dot_soa,
    dot_tile: neon::dot_tile,
    weighted_sum: neon::weighted_sum,
    gauss_from_norms: neon::gauss_from_norms,
    dot_tile_f32: neon::dot_tile_f32,
};

#[cfg(target_arch = "aarch64")]
mod neon {
    //! 2×f64 / 4×f32 kernels — the same algorithms as the avx2 module
    //! on half-width lanes. Safe wrappers around
    //! `#[target_feature(enable = "neon")]` bodies, installed only
    //! after `is_aarch64_feature_detected!("neon")`.

    use std::arch::aarch64::*;

    use crate::compute::fastexp;
    use crate::compute::fastexp::{C, EXP_UNDERFLOW_X, INV_LN2, LN2_HI, LN2_LO};

    /// Lane-wide certified exp; see `avx2::exp4` for the argument that
    /// this stays inside [`fastexp::EXP_MAX_REL_ERR`].
    // SAFETY: register-only arithmetic, no memory access; the caller
    // must hold the neon witness (every caller is an `_impl` in this
    // module, reached only through wrappers installed after runtime
    // detection).
    #[target_feature(enable = "neon")]
    unsafe fn exp2_lanes(x: float64x2_t) -> float64x2_t {
        // round-to-nearest(-even) — tie direction is inside the budget
        let k = vrndnq_f64(vmulq_f64(x, vdupq_n_f64(INV_LN2)));
        let r = vfmsq_f64(x, k, vdupq_n_f64(LN2_HI));
        let r = vfmsq_f64(r, k, vdupq_n_f64(LN2_LO));
        let mut p = vdupq_n_f64(C[11]);
        let mut j = 11;
        while j > 0 {
            j -= 1;
            p = vfmaq_f64(vdupq_n_f64(C[j]), p, r);
        }
        // k is integral, so the toward-zero convert is exact
        let ki = vcvtq_s64_f64(k);
        let biased = vaddq_s64(ki, vdupq_n_s64(1023));
        let scale = vreinterpretq_f64_s64(vshlq_n_s64::<52>(biased));
        let v = vmulq_f64(p, scale);
        let keep = vcgeq_f64(x, vdupq_n_f64(EXP_UNDERFLOW_X));
        vreinterpretq_f64_u64(vandq_u64(vreinterpretq_u64_f64(v), keep))
    }

    // SAFETY: caller must hold the neon witness (the safe wrapper
    // below is installed only after runtime detection); every
    // load/store stays inside `xs` — the vector loop requires
    // `j + 2 <= n` and the tail is scalar.
    #[target_feature(enable = "neon")]
    unsafe fn exp_block_impl(xs: &mut [f64]) {
        let n = xs.len();
        let ptr = xs.as_mut_ptr();
        let mut j = 0;
        while j + 2 <= n {
            let v = vld1q_f64(ptr.add(j));
            vst1q_f64(ptr.add(j), exp2_lanes(v));
            j += 2;
        }
        while j < n {
            xs[j] = fastexp::fast_exp(xs[j]);
            j += 1;
        }
    }

    pub(super) fn exp_block(xs: &mut [f64]) {
        // SAFETY: installed only after NEON runtime detection.
        unsafe { exp_block_impl(xs) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_soa_impl(q: &[f64], soa: &[f64], stride: usize, n: usize, out: &mut [f64]) {
        let out = &mut out[..n];
        out.fill(0.0);
        for (k, &qk) in q.iter().enumerate() {
            let lane = &soa[k * stride..k * stride + n];
            let qv = vdupq_n_f64(qk);
            let mut j = 0;
            while j + 2 <= n {
                let l = vld1q_f64(lane.as_ptr().add(j));
                let o = vld1q_f64(out.as_ptr().add(j));
                vst1q_f64(out.as_mut_ptr().add(j), vfmaq_f64(o, qv, l));
                j += 2;
            }
            while j < n {
                out[j] += qk * lane[j];
                j += 1;
            }
        }
    }

    pub(super) fn dot_soa(q: &[f64], soa: &[f64], stride: usize, n: usize, out: &mut [f64]) {
        // SAFETY: installed only after NEON runtime detection.
        unsafe { dot_soa_impl(q, soa, stride, n, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_tile_impl(
        qsoa: &[f64],
        qstride: usize,
        nq: usize,
        rsoa: &[f64],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f64],
    ) {
        debug_assert!(nq <= qstride && dims * qstride <= qsoa.len());
        debug_assert!(n <= rstride && nq * rstride <= tile.len());
        for t in 0..nq {
            tile[t * rstride..t * rstride + n].fill(0.0);
        }
        for k in 0..dims {
            let lane = &rsoa[k * rstride..k * rstride + n];
            for t in 0..nq {
                let qk = qsoa[k * qstride + t];
                let qv = vdupq_n_f64(qk);
                let row = &mut tile[t * rstride..t * rstride + n];
                let mut j = 0;
                while j + 2 <= n {
                    let l = vld1q_f64(lane.as_ptr().add(j));
                    let o = vld1q_f64(row.as_ptr().add(j));
                    vst1q_f64(row.as_mut_ptr().add(j), vfmaq_f64(o, qv, l));
                    j += 2;
                }
                while j < n {
                    row[j] += qk * lane[j];
                    j += 1;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn dot_tile(
        qsoa: &[f64],
        qstride: usize,
        nq: usize,
        rsoa: &[f64],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f64],
    ) {
        // SAFETY: installed only after NEON runtime detection.
        unsafe { dot_tile_impl(qsoa, qstride, nq, rsoa, rstride, n, dims, tile) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn weighted_sum_impl(w: &[f64], v: &[f64]) -> f64 {
        debug_assert_eq!(w.len(), v.len());
        let n = w.len();
        let mut acc = vdupq_n_f64(0.0);
        let mut j = 0;
        while j + 2 <= n {
            let wv = vld1q_f64(w.as_ptr().add(j));
            let vv = vld1q_f64(v.as_ptr().add(j));
            acc = vfmaq_f64(acc, wv, vv);
            j += 2;
        }
        let mut s = vgetq_lane_f64::<0>(acc) + vgetq_lane_f64::<1>(acc);
        while j < n {
            s += w[j] * v[j];
            j += 1;
        }
        s
    }

    pub(super) fn weighted_sum(w: &[f64], v: &[f64]) -> f64 {
        // SAFETY: installed only after NEON runtime detection.
        unsafe { weighted_sum_impl(w, v) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn gauss_from_norms_impl(
        neg: f64,
        qnorm: f64,
        rnorm: &[f64],
        vals: &mut [f64],
        n: usize,
    ) {
        let (vals, rnorm) = (&mut vals[..n], &rnorm[..n]);
        let qn = vdupq_n_f64(qnorm);
        let negv = vdupq_n_f64(neg);
        let two = vdupq_n_f64(2.0);
        let zero = vdupq_n_f64(0.0);
        let mut j = 0;
        while j + 2 <= n {
            let rn = vld1q_f64(rnorm.as_ptr().add(j));
            let v = vld1q_f64(vals.as_ptr().add(j));
            let sq = vfmsq_f64(vaddq_f64(qn, rn), two, v);
            let x = vmulq_f64(vmaxq_f64(sq, zero), negv);
            vst1q_f64(vals.as_mut_ptr().add(j), exp2_lanes(x));
            j += 2;
        }
        while j < n {
            vals[j] = fastexp::fast_exp((qnorm + rnorm[j] - 2.0 * vals[j]).max(0.0) * neg);
            j += 1;
        }
    }

    pub(super) fn gauss_from_norms(
        neg: f64,
        qnorm: f64,
        rnorm: &[f64],
        vals: &mut [f64],
        n: usize,
    ) {
        // SAFETY: installed only after NEON runtime detection.
        unsafe { gauss_from_norms_impl(neg, qnorm, rnorm, vals, n) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_tile_f32_impl(
        qsoa: &[f32],
        qstride: usize,
        nq: usize,
        rsoa: &[f32],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f32],
    ) {
        debug_assert!(nq <= qstride && dims * qstride <= qsoa.len());
        debug_assert!(n <= rstride && nq * rstride <= tile.len());
        for t in 0..nq {
            tile[t * rstride..t * rstride + n].fill(0.0);
        }
        for k in 0..dims {
            let lane = &rsoa[k * rstride..k * rstride + n];
            for t in 0..nq {
                let qk = qsoa[k * qstride + t];
                let qv = vdupq_n_f32(qk);
                let row = &mut tile[t * rstride..t * rstride + n];
                let mut j = 0;
                while j + 4 <= n {
                    let l = vld1q_f32(lane.as_ptr().add(j));
                    let o = vld1q_f32(row.as_ptr().add(j));
                    vst1q_f32(row.as_mut_ptr().add(j), vfmaq_f32(o, qv, l));
                    j += 4;
                }
                while j < n {
                    row[j] += qk * lane[j];
                    j += 1;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn dot_tile_f32(
        qsoa: &[f32],
        qstride: usize,
        nq: usize,
        rsoa: &[f32],
        rstride: usize,
        n: usize,
        dims: usize,
        tile: &mut [f32],
    ) {
        // SAFETY: installed only after NEON runtime detection.
        unsafe { dot_tile_f32_impl(qsoa, qstride, nq, rsoa, rstride, n, dims, tile) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn randvec(rng: &mut Pcg32, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| lo + (hi - lo) * rng.uniform()).collect()
    }

    #[test]
    fn mode_and_precision_parse_roundtrip() {
        assert_eq!(SimdMode::parse("AUTO"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("off"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("fast"), None);
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("F64"), Some(Precision::F64));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(SimdMode::default(), SimdMode::Auto);
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn select_off_is_the_scalar_table() {
        let off = select(SimdMode::Off);
        assert_eq!(off.backend, Backend::Scalar);
        assert!(std::ptr::eq(off, scalar()));
        // auto resolves to one fixed table for the whole process
        assert!(std::ptr::eq(select(SimdMode::Auto), select(SimdMode::Auto)));
    }

    #[test]
    fn scalar_table_delegates_verbatim() {
        let mut rng = Pcg32::new(2024);
        let n = 13;
        let stride = 16;
        let d = 3;
        let soa = randvec(&mut rng, d * stride, -1.0, 1.0);
        let q = randvec(&mut rng, d, -1.0, 1.0);
        let mut a = vec![0.0; stride];
        let mut b = vec![0.0; stride];
        (scalar().dot_soa)(&q, &soa, stride, n, &mut a);
        microkernel::dot_soa(&q, &soa, stride, n, &mut b);
        assert_eq!(a, b);
        let mut xs = randvec(&mut rng, 11, -30.0, 0.0);
        let mut ys = xs.clone();
        (scalar().exp_block)(&mut xs);
        fastexp::exp_block(&mut ys);
        assert_eq!(xs, ys);
    }

    /// Every active-table primitive agrees with the scalar reference on
    /// every lane-tail residue (n mod width ∈ {0..width−1}) and odd
    /// tile shapes — within the certified/documented slack, and
    /// bit-exactly when the active table *is* the scalar one.
    #[test]
    fn active_matches_scalar_on_all_lane_tails() {
        let act = active();
        let mut rng = Pcg32::new(7);
        for n in 0..=17 {
            for d in [1usize, 2, 3, 5] {
                let stride = n.max(1) + 3; // misaligned on purpose
                let rsoa = randvec(&mut rng, d * stride, -1.0, 1.0);
                let q = randvec(&mut rng, d, -1.0, 1.0);
                let mut got = vec![0.0; stride];
                let mut want = vec![0.0; stride];
                (act.dot_soa)(&q, &rsoa, stride, n, &mut got);
                (scalar().dot_soa)(&q, &rsoa, stride, n, &mut want);
                for j in 0..n {
                    let diff = (got[j] - want[j]).abs();
                    assert!(diff <= 1e-14 * (1.0 + want[j].abs()), "dot_soa n={n} d={d} j={j}");
                }

                let nq = 1 + n % super::super::tile::QUERY_TILE;
                let qstride = super::super::tile::QUERY_TILE;
                let qsoa = randvec(&mut rng, d * qstride, -1.0, 1.0);
                let mut tile_got = vec![0.0; nq * stride];
                let mut tile_want = vec![0.0; nq * stride];
                (act.dot_tile)(&qsoa, qstride, nq, &rsoa, stride, n, d, &mut tile_got);
                (scalar().dot_tile)(&qsoa, qstride, nq, &rsoa, stride, n, d, &mut tile_want);
                for i in 0..nq * stride {
                    let diff = (tile_got[i] - tile_want[i]).abs();
                    assert!(diff <= 1e-14 * (1.0 + tile_want[i].abs()), "tile n={n} d={d} i={i}");
                }

                let w = randvec(&mut rng, n, 0.0, 1.0);
                let v = randvec(&mut rng, n, 0.0, 1.0);
                let s_got = (act.weighted_sum)(&w, &v);
                let s_want = (scalar().weighted_sum)(&w, &v);
                let diff = (s_got - s_want).abs();
                assert!(diff <= 1e-13 * (1.0 + s_want.abs()), "wsum n={n}: {s_got} vs {s_want}");
            }
        }
    }

    #[test]
    fn active_exp_block_is_certified_on_all_tails() {
        let act = active();
        let mut rng = Pcg32::new(19);
        for n in 0..=9 {
            let xs = randvec(&mut rng, n, -40.0, 0.0);
            let mut got = xs.clone();
            (act.exp_block)(&mut got);
            for j in 0..n {
                let truth = xs[j].exp();
                let rel = (got[j] - truth).abs() / truth;
                assert!(rel <= fastexp::EXP_MAX_REL_ERR, "n={n} j={j} x={}", xs[j]);
            }
        }
        // underflow tail and ±0 behave like the scalar contract
        let mut edge = vec![-709.0, -708.0, 0.0, -0.0, -750.0];
        (act.exp_block)(&mut edge);
        assert_eq!(edge[0], 0.0);
        let t708 = (-708.0f64).exp();
        assert!((edge[1] - t708).abs() / t708 <= fastexp::EXP_MAX_REL_ERR);
        assert_eq!(edge[2], 1.0);
        assert_eq!(edge[3], 1.0);
        assert_eq!(edge[4], 0.0);
    }

    #[test]
    fn active_gauss_from_norms_matches_scalar_within_certificate() {
        let act = active();
        let mut rng = Pcg32::new(23);
        let neg = -1.0 / (2.0 * 0.35 * 0.35);
        for n in 0..=11 {
            let rnorm = randvec(&mut rng, n, 0.0, 3.0);
            let dots = randvec(&mut rng, n, -1.0, 1.0);
            let qnorm = rng.uniform() * 3.0;
            let mut got = dots.clone();
            let mut want = dots.clone();
            (act.gauss_from_norms)(neg, qnorm, &rnorm, &mut got, n);
            gauss_from_norms_scalar(neg, qnorm, &rnorm, &mut want, n);
            for j in 0..n {
                let rel = (got[j] - want[j]).abs() / want[j].max(1e-300);
                assert!(rel <= 4.0 * fastexp::EXP_MAX_REL_ERR, "n={n} j={j}: rel={rel:.2e}");
            }
        }
    }

    #[test]
    fn env_simd_parsing_covers_all_spellings() {
        use super::EnvSimd::*;
        assert_eq!(parse_env_simd(None), Auto);
        for v in ["", "auto", "AUTO", "on", "1", " auto "] {
            assert_eq!(parse_env_simd(Some(v)), Auto, "value {v:?}");
        }
        for v in ["off", "OFF", "scalar", "Scalar", "0", " off "] {
            assert_eq!(parse_env_simd(Some(v)), ForceOff, "value {v:?}");
        }
        for v in ["offf", "none", "2", "true", "avx2"] {
            assert_eq!(parse_env_simd(Some(v)), Unrecognized, "value {v:?}");
        }
    }

    #[test]
    fn f32_dot_tile_matches_f64_within_f32_slack() {
        let act = active();
        let mut rng = Pcg32::new(29);
        for n in 0..=19 {
            for d in [1usize, 3] {
                let stride = n.max(1) + 5;
                let rsoa = randvec(&mut rng, d * stride, -1.0, 1.0);
                let qstride = super::super::tile::QUERY_TILE;
                let nq = 1 + n % qstride;
                let qsoa = randvec(&mut rng, d * qstride, -1.0, 1.0);
                let rsoa32: Vec<f32> = rsoa.iter().map(|&v| v as f32).collect();
                let qsoa32: Vec<f32> = qsoa.iter().map(|&v| v as f32).collect();
                let mut t64 = vec![0.0f64; nq * stride];
                let mut t32 = vec![0.0f32; nq * stride];
                (scalar().dot_tile)(&qsoa, qstride, nq, &rsoa, stride, n, d, &mut t64);
                (act.dot_tile_f32)(&qsoa32, qstride, nq, &rsoa32, stride, n, d, &mut t32);
                for t in 0..nq {
                    for j in 0..n {
                        let a = f64::from(t32[t * stride + j]);
                        let b = t64[t * stride + j];
                        let tol = 1e-5 * (1.0 + b.abs()) * d as f64;
                        assert!((a - b).abs() <= tol, "n={n} d={d} t={t} j={j}");
                    }
                }
            }
        }
    }
}
