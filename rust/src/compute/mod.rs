//! The shared compute layer: one SoA (structure-of-arrays) batch
//! microkernel that every exhaustive Gaussian-summation loop in the
//! crate routes through.
//!
//! Before this module existed, `algo::naive`, the dual-tree leaf-leaf
//! base case, the FGT per-box direct path, the IFGT clustering loops and
//! the tiled runtime fallback each hand-rolled the same scalar
//! distance → exp → accumulate triple loop. Now there is exactly one
//! implementation to optimize for every current and future backend,
//! structured the way hardware likes it (following the blocked/batched
//! summation style of fast-sum-updating KDE, arXiv:1712.00993, and the
//! slicing fastsum line, arXiv:2401.08260):
//!
//! 1. **Load** ([`Scratch::load`]) — transpose a contiguous (or
//!    gathered) block of row-major points into dimension-major SoA
//!    lanes, so every subsequent pass streams unit-stride.
//! 2. **Distance** ([`microkernel::sqdist_soa`]) — blocked pairwise
//!    squared distances, dims in the outer loop, lanes in the inner:
//!    a branch-free, bounds-check-free loop the auto-vectorizer handles.
//! 3. **Kernel** ([`microkernel::gauss_in_place`]) — fused Gaussian
//!    `exp` over the block, no per-pair branching.
//! 4. **Accumulate** ([`microkernel::weighted_sum`]) — weighted
//!    reduction in ascending lane order.
//!
//! # Numerical contract
//!
//! Per (query, reference) pair the arithmetic is *identical in value
//! and order* to the scalar triple loop it replaced (dims accumulate
//! ascending, references accumulate ascending within a block, blocks
//! ascending), so results are bit-for-bit equal to the old code
//! whenever a range fits in one block, and within a few ulps otherwise.
//! [`reference::scalar_gauss_sums`] keeps the pre-microkernel loop
//! alive as the ground truth for tests and the `§basecase` ablation.
//!
//! # The fast tiled path
//!
//! On top of the bit-exact microkernel sits the GEMM-shaped fast base
//! case ([`tile`]): cached squared norms + a blocked dot-product tile
//! replace the per-query subtract-square-accumulate sweep, and the
//! certified polynomial [`fastexp`] replaces per-pair libm `exp`. Its
//! per-pair relative error is *certified* and charged against the
//! caller's ε budget by `errorcontrol::split_epsilon`; drivers that
//! serve as verification truth keep the exact path. The fast drivers
//! run on explicit vector lanes ([`simd`]): AVX2+FMA or NEON kernels
//! selected once per process by runtime feature detection, with the
//! scalar code kept verbatim as the bit-exact fallback, plus an
//! ε-charged f32 mixed-precision tile.
//!
//! # Allocation contract
//!
//! All block state lives in a caller-owned [`Scratch`] arena. Sizing it
//! once (e.g. to the tree's maximum leaf count) makes every later call
//! allocation-free — the dual-tree traversal holds one `Scratch` per
//! worker thread and performs **zero** allocations after prepare.

pub mod fastexp;
pub mod microkernel;
pub mod reference;
mod scratch;
pub mod simd;
pub mod tile;

pub use scratch::Scratch;

use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

/// Default block width (lanes) — 256 points × 8 bytes = one 2 KiB lane
/// per dimension, comfortably L1-resident alongside the weight and
/// distance lanes up to D = 16.
pub const BLOCK: usize = 256;

/// Exhaustive weighted Gaussian summation, blocked over references:
/// `out[qi] += Σ_r weights[r]·K(‖queries_qi − refs_r‖)` for every query
/// row. `block = 0` means "one block spanning all references" (the
/// unblocked scalar order). Accumulates into `out`.
pub fn gauss_sum_all(
    queries: &Matrix,
    refs: &Matrix,
    weights: &[f64],
    kernel: &GaussianKernel,
    block: usize,
    scratch: &mut Scratch,
    out: &mut [f64],
) {
    assert_eq!(queries.cols(), refs.cols(), "dimension mismatch");
    assert_eq!(weights.len(), refs.rows(), "weights length");
    assert_eq!(out.len(), queries.rows(), "output length");
    if refs.rows() == 0 {
        return; // nothing to accumulate (and step_by(0) would panic)
    }
    let block = if block == 0 { refs.rows() } else { block };
    for rb in (0..refs.rows()).step_by(block) {
        let rend = (rb + block).min(refs.rows());
        scratch.load(refs, rb, rend);
        scratch.load_weights(weights, rb, rend);
        for (qi, sum) in out.iter_mut().enumerate() {
            *sum += scratch.gauss_dot(kernel, queries.row(qi));
        }
    }
}

/// [`gauss_sum_all`] on the GEMM-shaped fast path: squared distances
/// from the norms outer sum, [`tile::QUERY_TILE`] queries per pass over
/// each reference block, and the certified [`fastexp`] instead of libm.
/// Per-pair kernel values carry relative error ≤
/// `errorcontrol::base_case_rel_err(dim, h, max‖x‖²)`; exhaustive
/// *truth* paths (`algo::naive::Naive::new`, verification baselines)
/// stay on the bit-exact [`gauss_sum_all`].
pub fn gauss_sum_all_fast(
    queries: &Matrix,
    refs: &Matrix,
    weights: &[f64],
    kernel: &GaussianKernel,
    block: usize,
    scratch: &mut Scratch,
    out: &mut [f64],
) {
    assert_eq!(queries.cols(), refs.cols(), "dimension mismatch");
    assert_eq!(weights.len(), refs.rows(), "weights length");
    assert_eq!(out.len(), queries.rows(), "output length");
    if refs.rows() == 0 {
        return;
    }
    let qnorms = tile::sq_norms(queries);
    let rnorms = tile::sq_norms(refs);
    let block = if block == 0 { refs.rows() } else { block };
    let lanes = simd::active();
    for rb in (0..refs.rows()).step_by(block) {
        let rend = (rb + block).min(refs.rows());
        scratch.load(refs, rb, rend);
        scratch.load_weights(weights, rb, rend);
        scratch.load_ref_norms(&rnorms, rb, rend);
        tile::gauss_sums_fast_on_loaded(
            scratch,
            kernel,
            queries,
            &qnorms,
            0,
            queries.rows(),
            out,
            lanes,
        );
    }
}

/// One query against a gathered reference subset:
/// `Σ_j weights[idx[j]]·K(‖q − refs_idx[j]‖)`. The one-shot gather
/// form — callers that revisit the same subset (e.g. FGT's sparse
/// boxes) should instead transpose once via
/// [`microkernel::transpose_rows_indexed`] and reuse the lanes.
pub fn gauss_sum_indexed(
    q: &[f64],
    refs: &Matrix,
    idx: &[usize],
    weights: &[f64],
    kernel: &GaussianKernel,
    scratch: &mut Scratch,
) -> f64 {
    scratch.load_indexed(refs, idx);
    scratch.load_weights_indexed(weights, idx);
    scratch.gauss_dot(kernel, q)
}

/// `v[k] = (x[k] − center[k]) / scale`, returning `‖v‖²` with dims
/// accumulated in ascending order — the scaled-offset form shared by
/// the IFGT source-accumulation and evaluation loops.
#[inline]
pub fn scaled_offset(x: &[f64], center: &[f64], scale: f64, v: &mut [f64]) -> f64 {
    debug_assert_eq!(x.len(), center.len());
    debug_assert_eq!(x.len(), v.len());
    let mut sq = 0.0;
    for k in 0..x.len() {
        let t = (x[k] - center[k]) / scale;
        v[k] = t;
        sq += t * t;
    }
    sq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::sqdist;
    use crate::util::Pcg32;

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn blocked_matches_scalar_reference_bitwise_when_unblocked() {
        let q = random(40, 3, 1);
        let r = random(90, 3, 2);
        let w: Vec<f64> = (0..90).map(|i| 0.5 + i as f64 * 0.01).collect();
        let kernel = GaussianKernel::new(0.3);
        let mut a = vec![0.0; 40];
        let mut b = vec![0.0; 40];
        reference::scalar_gauss_sums(&q, &r, &w, &kernel, &mut a);
        let mut scratch = Scratch::with_block(3, 90);
        gauss_sum_all(&q, &r, &w, &kernel, 0, &mut scratch, &mut b);
        assert_eq!(a, b, "block=0 must reproduce the scalar order bit-for-bit");
    }

    #[test]
    fn odd_block_sizes_match_within_ulps() {
        let q = random(30, 2, 3);
        let r = random(70, 2, 4);
        let w = vec![1.0; 70];
        let kernel = GaussianKernel::new(0.2);
        let mut want = vec![0.0; 30];
        reference::scalar_gauss_sums(&q, &r, &w, &kernel, &mut want);
        for block in [1, 7, 64, 256] {
            let mut scratch = Scratch::with_block(2, block);
            let mut got = vec![0.0; 30];
            gauss_sum_all(&q, &r, &w, &kernel, block, &mut scratch, &mut got);
            for i in 0..30 {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-12 * want[i].max(1.0),
                    "block={block} i={i}"
                );
            }
        }
    }

    #[test]
    fn fast_driver_matches_exact_within_certified_budget() {
        let q = random(37, 3, 8);
        let r = random(101, 3, 9);
        let w: Vec<f64> = (0..101).map(|i| 0.4 + 0.01 * i as f64).collect();
        let kernel = GaussianKernel::new(0.3);
        let mut exact = vec![0.0; 37];
        reference::scalar_gauss_sums(&q, &r, &w, &kernel, &mut exact);
        for block in [0, 16, 64] {
            let mut scratch = Scratch::new(3);
            let mut fast = vec![0.0; 37];
            gauss_sum_all_fast(&q, &r, &w, &kernel, block, &mut scratch, &mut fast);
            for i in 0..37 {
                let rel = (fast[i] - exact[i]).abs() / exact[i].max(1e-300);
                assert!(rel <= 1e-12, "block={block} i={i}: rel={rel:.2e}");
            }
        }
        // empty reference set is a no-op on the fast path too
        let empty = Matrix::zeros(0, 3);
        let mut out = vec![7.0; 37];
        gauss_sum_all_fast(&q, &empty, &[], &kernel, 0, &mut Scratch::new(3), &mut out);
        assert!(out.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn empty_reference_set_is_a_noop() {
        let q = random(3, 2, 20);
        let r = Matrix::zeros(0, 2);
        let kernel = GaussianKernel::new(0.5);
        let mut scratch = Scratch::new(2);
        let mut out = vec![1.0, 2.0, 3.0];
        // block = 0 must not panic via step_by(0); out is untouched
        gauss_sum_all(&q, &r, &[], &kernel, 0, &mut scratch, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn indexed_gather_matches_subset() {
        let r = random(50, 4, 5);
        let w: Vec<f64> = (0..50).map(|i| 1.0 + i as f64 * 0.02).collect();
        let idx = [3usize, 17, 4, 49, 0, 31];
        let q = vec![0.2, 0.4, 0.6, 0.8];
        let kernel = GaussianKernel::new(0.5);
        let mut scratch = Scratch::new(4);
        let got = gauss_sum_indexed(&q, &r, &idx, &w, &kernel, &mut scratch);
        let mut want = 0.0;
        for &i in &idx {
            want += w[i] * kernel.eval_sq(sqdist(&q, r.row(i)));
        }
        assert_eq!(got, want);
    }

    #[test]
    fn scaled_offset_matches_manual() {
        let x = [1.0, 2.0, 3.0];
        let c = [0.5, 1.0, -1.0];
        let mut v = [0.0; 3];
        let sq = scaled_offset(&x, &c, 2.0, &mut v);
        assert_eq!(v, [0.25, 0.5, 2.0]);
        assert!((sq - (0.0625 + 0.25 + 4.0)).abs() < 1e-15);
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        let kernel = GaussianKernel::new(0.4);
        let mut scratch = Scratch::new(2);
        // first use on one dataset, then a smaller one: stale lanes from
        // the first must not leak into the second
        let big = random(120, 2, 6);
        let wb = vec![1.0; 120];
        let mut out = vec![0.0; 1];
        let q = Matrix::from_rows(&[vec![0.5, 0.5]]);
        gauss_sum_all(&q, &big, &wb, &kernel, 256, &mut scratch, &mut out);
        let small = random(9, 2, 7);
        let ws = vec![1.0; 9];
        let mut got = vec![0.0; 1];
        gauss_sum_all(&q, &small, &ws, &kernel, 256, &mut scratch, &mut got);
        let mut want = vec![0.0; 1];
        reference::scalar_gauss_sums(&q, &small, &ws, &kernel, &mut want);
        assert_eq!(got, want);
    }
}
