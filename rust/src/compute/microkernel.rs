//! The block primitives under [`Scratch`](super::Scratch): plain free
//! functions over slices, kept separate so tests and benches can drive
//! them directly against the scalar reference.
//!
//! All loops are branch-free over `n` lanes with the slice lengths
//! hoisted (`&lane[..n]` re-slices) so the bounds checks vanish and the
//! auto-vectorizer sees straight-line streaming code. Ordering is part
//! of the contract (see the module docs of [`super`]): dims ascending,
//! lanes ascending.

use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

/// Transpose rows `[begin, end)` of a row-major matrix into dim-major
/// SoA lanes: `soa[k·stride + j] = pts[(begin+j), k]`.
pub fn transpose_rows(pts: &Matrix, begin: usize, end: usize, stride: usize, soa: &mut [f64]) {
    let d = pts.cols();
    let n = end - begin;
    debug_assert!(n <= stride && d * stride <= soa.len());
    for j in 0..n {
        let row = pts.row(begin + j);
        for k in 0..d {
            soa[k * stride + j] = row[k];
        }
    }
}

/// Gather `idx` rows of a row-major matrix into dim-major SoA lanes,
/// preserving `idx` order.
pub fn transpose_rows_indexed(pts: &Matrix, idx: &[usize], stride: usize, soa: &mut [f64]) {
    let d = pts.cols();
    debug_assert!(idx.len() <= stride && d * stride <= soa.len());
    for (j, &i) in idx.iter().enumerate() {
        let row = pts.row(i);
        for k in 0..d {
            soa[k * stride + j] = row[k];
        }
    }
}

/// `sq[j] = ‖q − lane_j‖²` over `n` SoA lanes, dims accumulated in
/// ascending order (bit-compatible with the scalar per-pair loop).
pub fn sqdist_soa(q: &[f64], soa: &[f64], stride: usize, n: usize, sq: &mut [f64]) {
    let sq = &mut sq[..n];
    sq.fill(0.0);
    for (k, &qk) in q.iter().enumerate() {
        let lane = &soa[k * stride..k * stride + n];
        for j in 0..n {
            let dd = qk - lane[j];
            sq[j] += dd * dd;
        }
    }
}

/// In place Gaussian over a block of squared distances:
/// `sq[j] ← K(sq[j])`. No per-pair branching — one fused exp pass.
pub fn gauss_in_place(kernel: &GaussianKernel, sq: &mut [f64]) {
    for v in sq.iter_mut() {
        *v = kernel.eval_sq(*v);
    }
}

/// Weighted reduction `Σ_j w[j]·v[j]` in ascending lane order.
pub fn weighted_sum(w: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), v.len());
    let mut acc = 0.0;
    for j in 0..w.len() {
        acc += w[j] * v[j];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::sqdist;
    use crate::util::Pcg32;

    #[test]
    fn transpose_and_sqdist_agree_with_rowwise() {
        let mut rng = Pcg32::new(11);
        let pts = Matrix::from_rows(
            &(0..20).map(|_| (0..3).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        );
        let stride = 32;
        let mut soa = vec![0.0; 3 * stride];
        transpose_rows(&pts, 4, 17, stride, &mut soa);
        let q = [0.3, 0.7, 0.1];
        let mut sq = vec![0.0; stride];
        sqdist_soa(&q, &soa, stride, 13, &mut sq);
        for j in 0..13 {
            assert_eq!(sq[j], sqdist(&q, pts.row(4 + j)), "lane {j}");
        }
    }

    #[test]
    fn indexed_transpose_preserves_order() {
        let pts = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        let mut soa = vec![0.0; 8];
        transpose_rows_indexed(&pts, &[3, 0, 2], 8, &mut soa);
        assert_eq!(&soa[..3], &[4.0, 1.0, 3.0]);
    }

    #[test]
    fn gauss_block_equals_pointwise_eval() {
        let kernel = GaussianKernel::new(0.7);
        let mut sq = vec![0.0, 0.5, 2.0, 9.0];
        let want: Vec<f64> = sq.iter().map(|&s| kernel.eval_sq(s)).collect();
        gauss_in_place(&kernel, &mut sq);
        assert_eq!(sq, want);
    }

    #[test]
    fn weighted_sum_ascending_order() {
        assert_eq!(weighted_sum(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 4.0 + 10.0 + 18.0);
        assert_eq!(weighted_sum(&[], &[]), 0.0);
    }
}
