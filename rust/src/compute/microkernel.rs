//! The block primitives under [`Scratch`](super::Scratch): plain free
//! functions over slices, kept separate so tests and benches can drive
//! them directly against the scalar reference.
//!
//! All loops are branch-free over `n` lanes with the slice lengths
//! hoisted (`&lane[..n]` re-slices) so the bounds checks vanish and the
//! auto-vectorizer sees straight-line streaming code. Ordering is part
//! of the contract (see the module docs of [`super`]): dims ascending,
//! lanes ascending.

use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

/// Transpose rows `[begin, end)` of a row-major matrix into dim-major
/// SoA lanes: `soa[k·stride + j] = pts[(begin+j), k]`.
pub fn transpose_rows(pts: &Matrix, begin: usize, end: usize, stride: usize, soa: &mut [f64]) {
    let d = pts.cols();
    let n = end - begin;
    debug_assert!(n <= stride && d * stride <= soa.len());
    for j in 0..n {
        let row = pts.row(begin + j);
        for k in 0..d {
            soa[k * stride + j] = row[k];
        }
    }
}

/// Gather `idx` rows of a row-major matrix into dim-major SoA lanes,
/// preserving `idx` order.
pub fn transpose_rows_indexed(pts: &Matrix, idx: &[usize], stride: usize, soa: &mut [f64]) {
    let d = pts.cols();
    debug_assert!(idx.len() <= stride && d * stride <= soa.len());
    for (j, &i) in idx.iter().enumerate() {
        let row = pts.row(i);
        for k in 0..d {
            soa[k * stride + j] = row[k];
        }
    }
}

/// `sq[j] = ‖q − lane_j‖²` over `n` SoA lanes, dims accumulated in
/// ascending order (bit-compatible with the scalar per-pair loop).
pub fn sqdist_soa(q: &[f64], soa: &[f64], stride: usize, n: usize, sq: &mut [f64]) {
    let sq = &mut sq[..n];
    sq.fill(0.0);
    for (k, &qk) in q.iter().enumerate() {
        let lane = &soa[k * stride..k * stride + n];
        for j in 0..n {
            let dd = qk - lane[j];
            sq[j] += dd * dd;
        }
    }
}

/// `out[j] = Σ_k q[k]·soa[k·stride + j]` over `n` SoA lanes — the
/// dot-product half of the norms-trick squared distance
/// `‖q − r‖² = ‖q‖² + ‖r‖² − 2·q·r` used by the tiled base case
/// ([`crate::compute::tile`]).
pub fn dot_soa(q: &[f64], soa: &[f64], stride: usize, n: usize, out: &mut [f64]) {
    let out = &mut out[..n];
    out.fill(0.0);
    for (k, &qk) in q.iter().enumerate() {
        let lane = &soa[k * stride..k * stride + n];
        for j in 0..n {
            out[j] += qk * lane[j];
        }
    }
}

/// GEMM-shaped dot products of a query tile against reference lanes:
/// `tile[t·rstride + j] = Σ_k qsoa[k·qstride + t]·rsoa[k·rstride + j]`
/// for `t < nq`, `j < n`. Each reference lane is streamed once per
/// *tile* instead of once per query — the register/cache reuse the
/// single-query sweep leaves on the table.
pub fn dot_tile(
    qsoa: &[f64],
    qstride: usize,
    nq: usize,
    rsoa: &[f64],
    rstride: usize,
    n: usize,
    dims: usize,
    tile: &mut [f64],
) {
    debug_assert!(nq <= qstride && dims * qstride <= qsoa.len());
    debug_assert!(n <= rstride && nq * rstride <= tile.len());
    for t in 0..nq {
        tile[t * rstride..t * rstride + n].fill(0.0);
    }
    for k in 0..dims {
        let lane = &rsoa[k * rstride..k * rstride + n];
        for t in 0..nq {
            let qv = qsoa[k * qstride + t];
            let row = &mut tile[t * rstride..t * rstride + n];
            for j in 0..n {
                row[j] += qv * lane[j];
            }
        }
    }
}

/// In place Gaussian over a block of squared distances:
/// `sq[j] ← K(sq[j])`. No per-pair branching — one fused exp pass.
pub fn gauss_in_place(kernel: &GaussianKernel, sq: &mut [f64]) {
    for v in sq.iter_mut() {
        *v = kernel.eval_sq(*v);
    }
}

/// Weighted reduction `Σ_j w[j]·v[j]` in ascending lane order.
pub fn weighted_sum(w: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), v.len());
    let mut acc = 0.0;
    for j in 0..w.len() {
        acc += w[j] * v[j];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::sqdist;
    use crate::util::Pcg32;

    #[test]
    fn transpose_and_sqdist_agree_with_rowwise() {
        let mut rng = Pcg32::new(11);
        let pts = Matrix::from_rows(
            &(0..20).map(|_| (0..3).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        );
        let stride = 32;
        let mut soa = vec![0.0; 3 * stride];
        transpose_rows(&pts, 4, 17, stride, &mut soa);
        let q = [0.3, 0.7, 0.1];
        let mut sq = vec![0.0; stride];
        sqdist_soa(&q, &soa, stride, 13, &mut sq);
        for j in 0..13 {
            assert_eq!(sq[j], sqdist(&q, pts.row(4 + j)), "lane {j}");
        }
    }

    #[test]
    fn indexed_transpose_preserves_order() {
        let pts = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        let mut soa = vec![0.0; 8];
        transpose_rows_indexed(&pts, &[3, 0, 2], 8, &mut soa);
        assert_eq!(&soa[..3], &[4.0, 1.0, 3.0]);
    }

    #[test]
    fn dot_soa_matches_manual_dot() {
        let pts = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, -1.0], vec![0.5, 0.25]]);
        let stride = 4;
        let mut soa = vec![0.0; 2 * stride];
        transpose_rows(&pts, 0, 3, stride, &mut soa);
        let q = [2.0, -0.5];
        let mut out = vec![0.0; stride];
        dot_soa(&q, &soa, stride, 3, &mut out);
        for j in 0..3 {
            let want: f64 = q.iter().zip(pts.row(j)).map(|(a, b)| a * b).sum();
            assert_eq!(out[j], want, "lane {j}");
        }
    }

    #[test]
    fn dot_tile_matches_per_query_dot_soa() {
        let mut rng = Pcg32::new(31);
        let d = 3;
        let refs = Matrix::from_rows(
            &(0..11).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        );
        let queries = Matrix::from_rows(
            &(0..5).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        );
        let rstride = 16;
        let mut rsoa = vec![0.0; d * rstride];
        transpose_rows(&refs, 0, 11, rstride, &mut rsoa);
        let qstride = 8;
        let mut qsoa = vec![0.0; d * qstride];
        for t in 0..5 {
            for k in 0..d {
                qsoa[k * qstride + t] = queries.get(t, k);
            }
        }
        let mut tile = vec![0.0; 5 * rstride];
        dot_tile(&qsoa, qstride, 5, &rsoa, rstride, 11, d, &mut tile);
        let mut per_query = vec![0.0; rstride];
        for t in 0..5 {
            dot_soa(queries.row(t), &rsoa, rstride, 11, &mut per_query);
            assert_eq!(&tile[t * rstride..t * rstride + 11], &per_query[..11], "tile row {t}");
        }
    }

    #[test]
    fn gauss_block_equals_pointwise_eval() {
        let kernel = GaussianKernel::new(0.7);
        let mut sq = vec![0.0, 0.5, 2.0, 9.0];
        let want: Vec<f64> = sq.iter().map(|&s| kernel.eval_sq(s)).collect();
        gauss_in_place(&kernel, &mut sq);
        assert_eq!(sq, want);
    }

    #[test]
    fn weighted_sum_ascending_order() {
        assert_eq!(weighted_sum(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 4.0 + 10.0 + 18.0);
        assert_eq!(weighted_sum(&[], &[]), 0.0);
    }
}
