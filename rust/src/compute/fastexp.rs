//! A certified fast exponential for kernel blocks.
//!
//! The Gaussian base case spends most of its time in `f64::exp`: one
//! libm call per (query, reference) pair, opaque to the vectorizer. The
//! paper's error-control scheme is explicitly designed to "integrate
//! any arbitrary approximation method", which licenses replacing libm
//! with a *certified* polynomial approximation and charging its bound
//! against the same ε budget (`errorcontrol::split_epsilon` performs
//! the split; see DESIGN.md §"Tiled base cases").
//!
//! [`fast_exp`] is the classic branch-free range reduction
//!
//! ```text
//!   k = round(x / ln 2),   r = x − k·ln 2   (|r| ≤ ln(2)/2 + 1 ulp)
//!   exp(x) = 2^k · exp(r) ≈ 2^k · P₁₁(r)
//! ```
//!
//! with `P₁₁` the degree-11 Taylor polynomial of `exp` and the `2^k`
//! scaling done by assembling the exponent bits directly — no table, no
//! data-dependent branch, and the whole body inlines into the block
//! loops of [`exp_block`] where it auto-vectorizes.
//!
//! # Certified error bound
//!
//! On the domain `[EXP_UNDERFLOW_X, 709]` the relative error versus the
//! true exponential is at most [`EXP_MAX_REL_ERR`] = 1e-13. The budget
//! decomposes as follows (u = 2⁻⁵³, |r| ≤ ρ = ln(2)/2 ≈ 0.34658):
//!
//! * **Truncation.** The Taylor remainder after degree 11 is
//!   `|exp(r) − P₁₁(r)| ≤ ρ¹²/12! · e^ρ ≤ 8.9e-15`; relative to
//!   `exp(r) ≥ e^(−ρ) ≈ 0.7071` that is ≤ 1.26e-14.
//! * **Range reduction.** `k·LN2_HI` is exact (LN2_HI carries 20
//!   trailing zero bits and |k| ≤ 1024 < 2²⁰), and the first
//!   subtraction cancels exactly, so the computed `r` differs from the
//!   true reduced argument by ≤ 1 ulp(ρ) + |k|·ulp(LN2_LO) ≤ 6e-17;
//!   `exp`'s sensitivity turns |Δr| into the same relative error.
//! * **Polynomial rounding.** Horner with 11 fused steps on |r| ≤ ρ
//!   accumulates ≤ 24·u·e^ρ/e^(−ρ) ≤ 5.3e-15 relative.
//! * **Scaling.** Multiplying by the exactly-representable power of two
//!   `2^k` adds ≤ 1 ulp = 1.1e-16 (the result is normal on the stated
//!   domain, so no double-rounding in the subnormal range).
//!
//! Total ≤ 2.0e-14, certified as 1e-13 with a 5× margin; the property
//! suite (`rust/tests/tiled_basecase.rs`) checks the bound on 10⁶
//! random inputs plus the adversarial seams (reduction boundaries,
//! underflow tail, ±0).
//!
//! Below `EXP_UNDERFLOW_X` the function returns exactly 0.0. True
//! values there are < e⁻⁷⁰⁸ ≈ 3.3e-308 (the bottom of the normal f64
//! range), so zeroing the tail contributes < 3.3e-308·W of *absolute*
//! error to any Gaussian sum — negligible against every representable
//! error budget (see `errorcontrol::split_epsilon` for where this is
//! accounted).

/// Certified relative-error bound of [`fast_exp`] / [`exp_block`] on
/// `[EXP_UNDERFLOW_X, 709]` (derivation in the module docs).
pub const EXP_MAX_REL_ERR: f64 = 1e-13;

/// Arguments below this return exactly 0.0. Chosen so that every
/// non-zero result is a *normal* f64 (e⁻⁷⁰⁸ > DBL_MIN), keeping the
/// bit-assembled `2^k` scaling exact.
pub const EXP_UNDERFLOW_X: f64 = -708.0;

/// 1/ln(2).
pub(crate) const INV_LN2: f64 = std::f64::consts::LOG2_E;
/// High part of ln(2): 20 trailing zero mantissa bits, so `k·LN2_HI`
/// is exact for |k| < 2²⁰ (fdlibm's split).
#[allow(clippy::excessive_precision)]
pub(crate) const LN2_HI: f64 = 6.931_471_803_691_238_164_90e-1;
/// Low part: ln(2) − LN2_HI to full precision.
#[allow(clippy::excessive_precision)]
pub(crate) const LN2_LO: f64 = 1.908_214_929_270_587_700_02e-10;

/// Taylor coefficients 1/j! for j = 0..=11.
pub(crate) const C: [f64; 12] = [
    1.0,
    1.0,
    1.0 / 2.0,
    1.0 / 6.0,
    1.0 / 24.0,
    1.0 / 120.0,
    1.0 / 720.0,
    1.0 / 5040.0,
    1.0 / 40320.0,
    1.0 / 362880.0,
    1.0 / 3628800.0,
    1.0 / 39916800.0,
];

/// Branch-free range-reduced polynomial `exp` with the certified bound
/// [`EXP_MAX_REL_ERR`] on `[EXP_UNDERFLOW_X, 709]`; exactly 0.0 below,
/// unspecified above 709 and on non-finite input (the kernel paths
/// only produce finite non-positive arguments).
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    let k = (x * INV_LN2).round();
    let r = (x - k * LN2_HI) - k * LN2_LO;
    // degree-11 Taylor, Horner form
    let mut p = C[11];
    p = p * r + C[10];
    p = p * r + C[9];
    p = p * r + C[8];
    p = p * r + C[7];
    p = p * r + C[6];
    p = p * r + C[5];
    p = p * r + C[4];
    p = p * r + C[3];
    p = p * r + C[2];
    p = p * r + C[1];
    p = p * r + C[0];
    // 2^k assembled from the exponent bits; the clamp only engages
    // outside the certified domain, where the select below discards
    // the value anyway (no wrap-around garbage reaches a caller).
    let biased = (1023i64 + k as i64).clamp(0, 2046) as u64;
    let scale = f64::from_bits(biased << 52);
    // compiles to a select on the already-computed value, not a branch
    // around the computation
    if x < EXP_UNDERFLOW_X {
        return 0.0;
    }
    p * scale
}

/// In-place [`fast_exp`] over a block of exponents — the fused tail of
/// the tiled base case (`compute::tile`): one straight-line pass the
/// auto-vectorizer handles, no per-element libm call.
#[inline]
pub fn exp_block(xs: &mut [f64]) {
    for v in xs.iter_mut() {
        *v = fast_exp(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_err(x: f64) -> f64 {
        let truth = x.exp();
        let got = fast_exp(x);
        (got - truth).abs() / truth
    }

    #[test]
    fn exact_at_zero_both_signs() {
        assert_eq!(fast_exp(0.0), 1.0);
        assert_eq!(fast_exp(-0.0), 1.0);
    }

    #[test]
    fn certified_bound_on_spot_checks() {
        for x in [
            -1e-300, -1e-16, -0.1, -0.5, -1.0, -2.0, -10.0, -87.3, -345.678, -700.0, -707.999,
        ] {
            assert!(rel_err(x) <= EXP_MAX_REL_ERR, "x={x}: rel={:.2e}", rel_err(x));
        }
    }

    #[test]
    fn positive_domain_also_within_bound() {
        // clamped-negative squared distances can round to tiny positive
        // exponents; the certification extends to [0, 709]
        for x in [1e-18, 0.3, 1.0, 100.0, 700.0] {
            assert!(rel_err(x) <= EXP_MAX_REL_ERR, "x={x}");
        }
    }

    #[test]
    fn underflow_tail_is_exactly_zero() {
        for x in [-708.0001, -710.0, -745.0, -1e4, -1e300, f64::MIN] {
            assert_eq!(fast_exp(x), 0.0, "x={x}");
        }
        // the boundary itself is still computed (and positive)
        assert!(fast_exp(EXP_UNDERFLOW_X) > 0.0);
    }

    #[test]
    fn reduction_seams() {
        // half-ln2 multiples sit exactly on the k-rounding boundary
        let ulp_up = |x: f64| f64::from_bits(x.to_bits() - 1); // toward 0 for negative x
        let ulp_down = |x: f64| f64::from_bits(x.to_bits() + 1);
        let ln2 = std::f64::consts::LN_2;
        // under the interpreter, sample the seams instead of walking
        // all of them — the full sweep runs on the native CI legs
        let step = if cfg!(miri) { 37 } else { 1 };
        for m in (1..1000).step_by(step) {
            let x = -(m as f64) * 0.5 * ln2;
            assert!(rel_err(x) <= EXP_MAX_REL_ERR, "m={m}");
            assert!(rel_err(ulp_up(x)) <= EXP_MAX_REL_ERR, "m={m}+ulp");
            assert!(rel_err(ulp_down(x)) <= EXP_MAX_REL_ERR, "m={m}-ulp");
        }
    }

    #[test]
    fn block_matches_scalar() {
        let mut xs = vec![-0.0, -0.25, -3.5, -100.0, -720.0];
        let want: Vec<f64> = xs.iter().map(|&x| fast_exp(x)).collect();
        exp_block(&mut xs);
        assert_eq!(xs, want);
    }
}
