//! Mini property-testing framework (proptest is unavailable in the
//! offline vendor set). Properties run against a deterministic PCG
//! stream; failures report the failing case index and seed so any case
//! reproduces exactly with `FASTGAUSS_PROP_SEED`/`FASTGAUSS_PROP_CASES`.
//!
//! ```no_run
//! use fastgauss::prop::{forall, Gen};
//! forall("addition commutes", 64, |g: &mut Gen| {
//!     let (a, b) = (g.f64_in(-1e6, 1e6), g.f64_in(-1e6, 1e6));
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::util::Pcg32;

/// Random-input source handed to each property case.
pub struct Gen {
    rng: Pcg32,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg32::new(seed) }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.uniform() < 0.5
    }

    /// log-uniform positive value in [lo, hi] — bandwidths, tolerances.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.uniform_in(lo.ln(), hi.ln())).exp()
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// A fresh vector of values.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Clustered point rows (the data regime the algorithms target).
    pub fn clustered_points(&mut self, n: usize, d: usize) -> Vec<Vec<f64>> {
        let k = self.usize_in(2, 6);
        let centers: Vec<Vec<f64>> =
            (0..k).map(|_| (0..d).map(|_| self.rng.uniform()).collect()).collect();
        (0..n)
            .map(|i| {
                let c = &centers[i % k];
                (0..d).map(|j| c[j] + 0.05 * self.rng.normal()).collect()
            })
            .collect()
    }

    /// Expose the raw RNG for bespoke structures.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of a property; panics with a reproducible
/// report on the first failure. The property returns `Err(detail)` to
/// fail. Environment overrides: `FASTGAUSS_PROP_SEED` (base seed),
/// `FASTGAUSS_PROP_CASES` (case count multiplier ×).
pub fn forall<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("FASTGAUSS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xF457_6A55u64);
    let mult: usize = std::env::var("FASTGAUSS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let total = cases * mult;
    for case in 0..total {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut gen = Gen::new(seed);
        if let Err(detail) = property(&mut gen) {
            panic!(
                "property {name:?} failed at case {case}/{total} \
                 (reproduce with FASTGAUSS_PROP_SEED={base_seed}, case seed {seed:#x}): {detail}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("abs is nonneg", 100, |g| {
            let x = g.f64_in(-10.0, 10.0);
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\" failed")]
    fn failing_property_panics_with_report() {
        forall("always fails", 5, |_g| Err("nope".to_string()));
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.f64_in(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
            let u = g.usize_in(5, 7);
            assert!((5..=7).contains(&u));
            let l = g.log_uniform(1e-3, 1e3);
            assert!((1e-3..=1e3).contains(&l));
        }
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        forall("collect", 3, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second = Vec::new();
        forall("collect", 3, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn clustered_points_shape() {
        let mut g = Gen::new(2);
        let pts = g.clustered_points(50, 3);
        assert_eq!(pts.len(), 50);
        assert!(pts.iter().all(|p| p.len() == 3));
    }
}
