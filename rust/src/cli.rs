//! Command-line interface.
//!
//! ```text
//! fastgauss table    [--dataset astro2d --n 5000 ...]   paper-style table
//! fastgauss kde      [--dataset X --h 0|H --out f.csv]  density + LSCV h*
//! fastgauss datagen  [--dataset X --out f.csv]          write a dataset
//! fastgauss selftest [--n 500]                          verify all engines
//! fastgauss runtime  [--n 2000]                         PJRT artifact check
//! ```

use crate::util::error::Result;
use crate::{anyhow, bail};

use crate::algo::dualtree::DualTreeConfig;
use crate::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem, SweepEngine};
use crate::config::RunConfig;
use crate::coordinator::{run_sweep, AlgoSpec, SweepConfig};
use crate::data;
use crate::kde::bandwidth::{log_grid, silverman};
use crate::kde::lscv::select_bandwidth_engine;

const USAGE: &str = "usage: fastgauss <table|kde|datagen|selftest|runtime> [--option value ...]
options: --dataset NAME --n N --seed S --epsilon E --algos a,b,c
         --workers W --leaf-size L --multipliers m1,m2 --h H --out FILE
         --config FILE";

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let mut cfg = RunConfig::default();
    cfg.apply_args(&args[1..])?;
    match cmd.as_str() {
        "table" => cmd_table(&cfg),
        "kde" => cmd_kde(&cfg),
        "datagen" => cmd_datagen(&cfg),
        "selftest" => cmd_selftest(&cfg),
        "runtime" => cmd_runtime(&cfg),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn load_dataset(cfg: &RunConfig) -> Result<data::Dataset> {
    if cfg.dataset.ends_with(".csv") {
        let m = data::csv::load(std::path::Path::new(&cfg.dataset))?;
        Ok(data::Dataset::new(cfg.dataset.clone(), data::scale::to_unit_cube(&m)))
    } else {
        data::by_name(&cfg.dataset, cfg.n, cfg.seed)
            .ok_or_else(|| anyhow!("unknown dataset {:?} (see `data::PAPER_SUITE`)", cfg.dataset))
    }
}

fn pick_h_star(cfg: &RunConfig, ds: &data::Dataset) -> Result<f64> {
    if cfg.bandwidth > 0.0 {
        return Ok(cfg.bandwidth);
    }
    // LSCV around the Silverman pilot with the DITO variant on a
    // prepared sweep engine: one tree build for the whole grid,
    // parallel across grid bandwidths.
    let pilot = silverman(&ds.points);
    let grid = log_grid(pilot, 0.1, 10.0, 9);
    let engine = SweepEngine::for_kde(&ds.points, cfg.leaf_size).with_threads(cfg.workers);
    let (h, _) = select_bandwidth_engine(&engine, &grid, cfg.epsilon, &DualTreeConfig::default())
        .map_err(|e| anyhow!("LSCV failed: {e}"))?;
    Ok(h)
}

fn cmd_table(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let h_star = pick_h_star(cfg, &ds)?;
    let algorithms: Vec<AlgoSpec> = cfg
        .algorithms
        .iter()
        .map(|s| AlgoSpec::parse(s).ok_or_else(|| anyhow!("unknown algorithm {s:?}")))
        .collect::<Result<_>>()?;
    let sweep = SweepConfig {
        dataset: ds,
        epsilon: cfg.epsilon,
        h_star,
        multipliers: cfg.multipliers.clone(),
        algorithms,
        workers: cfg.workers,
        leaf_size: cfg.leaf_size,
    };
    let res = run_sweep(&sweep);
    print!("{}", crate::coordinator::report::render_table(&res));
    if let Some(out) = &cfg.out {
        std::fs::write(out, crate::coordinator::report::render_csv(&res))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_kde(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let engine = crate::algo::dito::Dito::default();
    let h = pick_h_star(cfg, &ds)?;
    let dens = crate::kde::density_at_points(&ds.points, h, cfg.epsilon, &engine)
        .map_err(|e| anyhow!("{e}"))?;
    println!(
        "dataset={} n={} D={} h={h:.6} mean_density={:.6e}",
        ds.name,
        ds.len(),
        ds.dim(),
        crate::util::stats::mean(&dens)
    );
    if let Some(out) = &cfg.out {
        let mut rows = Vec::with_capacity(dens.len());
        for (i, d) in dens.iter().enumerate() {
            let mut row = ds.points.row(i).to_vec();
            row.push(*d);
            rows.push(row);
        }
        data::csv::save(std::path::Path::new(out), &crate::geometry::Matrix::from_rows(&rows))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_datagen(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let out = cfg.out.clone().unwrap_or_else(|| format!("{}.csv", ds.name));
    data::csv::save(std::path::Path::new(&out), &ds.points)?;
    println!("wrote {out}: {} × {}", ds.len(), ds.dim());
    Ok(())
}

fn cmd_selftest(cfg: &RunConfig) -> Result<()> {
    use crate::algo::{dfd::Dfd, dfdo::Dfdo, dfto::Dfto, dito::Dito};
    let ds = load_dataset(cfg)?;
    let pilot = silverman(&ds.points);
    let mut ok = true;
    for mult in [1e-2, 1.0, 1e2] {
        let h = pilot * mult;
        let p = GaussSumProblem::kde(&ds.points, h, cfg.epsilon);
        let exact = Naive::new().run(&p).unwrap().sums;
        let engines: Vec<Box<dyn GaussSum>> = vec![
            Box::new(Dfd::new()),
            Box::new(Dfdo::new()),
            Box::new(Dfto::new()),
            Box::new(Dito::default()),
        ];
        for e in engines {
            let res = e.run(&p).map_err(|err| anyhow!("{}: {err}", e.name()))?;
            let rel = max_relative_error(&res.sums, &exact);
            let pass = rel <= cfg.epsilon * (1.0 + 1e-9);
            ok &= pass;
            println!(
                "{:<6} h={h:<12.5} rel_err={rel:.2e}  {}",
                e.name(),
                if pass { "OK" } else { "FAIL" }
            );
        }
    }
    if !ok {
        bail!("selftest FAILED");
    }
    println!("selftest OK");
    Ok(())
}

fn cmd_runtime(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let tiled = crate::runtime::TiledNaive::load(ds.dim())?;
    let h = silverman(&ds.points);
    let p = GaussSumProblem::kde(&ds.points, h, cfg.epsilon);
    let (pjrt, pjrt_secs) = crate::util::timer::time_it(|| tiled.run(&p).unwrap());
    let (rust, rust_secs) = crate::util::timer::time_it(|| Naive::new().run(&p).unwrap());
    let rel = max_relative_error(&pjrt.sums, &rust.sums);
    println!(
        "{} D={}: rel_err vs rust naive = {rel:.2e}  ({} {:.3}s, rust {:.3}s)",
        tiled.name(),
        ds.dim(),
        if tiled.is_cpu_fallback() { "cpu-fallback" } else { "pjrt" },
        pjrt_secs,
        rust_secs
    );
    if rel > 1e-9 {
        bail!("runtime mismatch");
    }
    println!("runtime OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(run(&["bogus".to_string()]).is_err());
    }

    #[test]
    fn no_args_prints_usage() {
        assert!(run(&[]).is_ok());
    }

    #[test]
    fn selftest_small() {
        let args: Vec<String> = ["selftest", "--n", "200", "--dataset", "astro2d"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        run(&args).unwrap();
    }

    #[test]
    fn datagen_writes_csv() {
        let out = std::env::temp_dir().join("fg_cli_datagen.csv");
        let args: Vec<String> = [
            "datagen",
            "--n",
            "50",
            "--dataset",
            "bio5",
            "--out",
            out.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(&args).unwrap();
        let m = data::csv::load(&out).unwrap();
        assert_eq!(m.rows(), 50);
        assert_eq!(m.cols(), 5);
    }
}
