//! Command-line interface.
//!
//! ```text
//! fastgauss table    [--dataset astro2d --n 5000 ...]   paper-style table
//! fastgauss kde      [--dataset X --h 0|H --method auto --out f.csv]
//!                                                       density + LSCV h*
//! fastgauss datagen  [--dataset X --out f.csv]          write a dataset
//! fastgauss selftest [--n 500]                          verify all engines
//! fastgauss runtime  [--n 2000]                         PJRT artifact check
//! ```
//!
//! Every command runs on the `api::Session` front door; `--method`
//! (default `auto`) picks the summation engine for `kde`, with `auto`
//! resolved per problem by the session's cost model. `--workers W`
//! sizes the session's shared work-stealing pool — sweep cells, batch
//! requests and their nested traversal tasks all run on it, and
//! results of the deterministic engines (Naive, dual-tree, FGT) are
//! bit-identical for every width (IFGT tunes against a wall-clock
//! budget, so its cells are ε-verified but timing-dependent).
//! `--kernel` (default `gaussian`) selects the kernel family for
//! `table`, `kde` and `selftest`: non-Gaussian families are answered
//! through the certified sum-of-Gaussians batch path under the
//! weight-scaled absolute guarantee max_q |G̃−G| ≤ ε·W.

use crate::util::error::Result;
use crate::{anyhow, bail};

use crate::api::{EvalRequest, Kernel, Method, PrepareOptions, Session};
use crate::algo::{
    max_relative_error, max_weight_scaled_error, naive::Naive, AlgoError, GaussSum,
    GaussSumProblem,
};
use crate::config::RunConfig;
use crate::coordinator::{run_sweep, AlgoSpec, SweepConfig};
use crate::data;
use crate::kde::bandwidth::{log_grid, silverman};
use crate::kde::lscv::select_bandwidth_session;

const USAGE: &str = "usage: fastgauss <table|kde|datagen|selftest|runtime> [--option value ...]
options: --dataset NAME --n N --seed S --epsilon E --algos a,b,c
         --workers W --leaf-size L --multipliers m1,m2 --h H
         --method naive|fgt|ifgt|dfd|dfdo|dfto|dito|sliced|auto
         --kernel gaussian|laplace|matern32|matern52|imq (default gaussian)
         --fast-exp true|false (certified tiled base case; default true)
         --simd auto|off (vector lanes in the fast tiles; default auto)
         --precision f64|f32 (certified mixed-precision tile; default f64)
         --slices P (sliced engine P-doubling start; default engine-chosen)
         --out FILE --config FILE";

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let mut cfg = RunConfig::default();
    cfg.apply_args(&args[1..])?;
    match cmd.as_str() {
        "table" => cmd_table(&cfg),
        "kde" => cmd_kde(&cfg),
        "datagen" => cmd_datagen(&cfg),
        "selftest" => cmd_selftest(&cfg),
        "runtime" => cmd_runtime(&cfg),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn load_dataset(cfg: &RunConfig) -> Result<data::Dataset> {
    if cfg.dataset.ends_with(".csv") {
        let m = data::csv::load(std::path::Path::new(&cfg.dataset))?;
        Ok(data::Dataset::new(cfg.dataset.clone(), data::scale::to_unit_cube(&m)))
    } else {
        data::by_name(&cfg.dataset, cfg.n, cfg.seed)
            .ok_or_else(|| anyhow!("unknown dataset {:?} (see `data::PAPER_SUITE`)", cfg.dataset))
    }
}

fn session_for<'d>(cfg: &RunConfig, ds: &'d data::Dataset) -> Session<'d> {
    Session::prepare(
        &ds.points,
        PrepareOptions {
            leaf_size: cfg.leaf_size,
            threads: cfg.workers,
            fast_exp: cfg.fast_exp,
            simd: cfg.simd,
            precision: cfg.precision,
            kernel: cfg.kernel,
            slices: cfg.slices,
            ..Default::default()
        },
    )
}

/// LSCV around the Silverman pilot on a prepared session: one tree
/// build for the whole grid, parallel across grid bandwidths, with the
/// configured `--method` (default: automatic selection per bandwidth).
fn pick_h_star(cfg: &RunConfig, session: &Session<'_>) -> Result<f64> {
    if cfg.bandwidth > 0.0 {
        return Ok(cfg.bandwidth);
    }
    let pilot = silverman(session.data());
    if !cfg.kernel.is_gaussian() {
        // LSCV's closed form is Gaussian-specific; non-Gaussian runs
        // use the Silverman pilot as the scale (override with --h)
        return Ok(pilot);
    }
    let grid = log_grid(pilot, 0.1, 10.0, 9);
    let (h, _) = select_bandwidth_session(session, &grid, cfg.epsilon, cfg.method)
        .map_err(|e| anyhow!("LSCV failed: {e}"))?;
    Ok(h)
}

fn cmd_table(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let h_star = if cfg.bandwidth > 0.0 {
        cfg.bandwidth
    } else {
        pick_h_star(cfg, &session_for(cfg, &ds))?
    };
    let algorithms: Vec<AlgoSpec> = cfg
        .algorithms
        .iter()
        .map(|s| AlgoSpec::parse(s).ok_or_else(|| anyhow!("unknown algorithm {s:?}")))
        .collect::<Result<_>>()?;
    let sweep = SweepConfig {
        dataset: ds,
        epsilon: cfg.epsilon,
        h_star,
        multipliers: cfg.multipliers.clone(),
        algorithms,
        workers: cfg.workers,
        leaf_size: cfg.leaf_size,
        fast_exp: cfg.fast_exp,
        simd: cfg.simd,
        precision: cfg.precision,
        kernel: cfg.kernel,
    };
    let res = run_sweep(&sweep);
    print!("{}", crate::coordinator::report::render_table(&res));
    if let Some(out) = &cfg.out {
        std::fs::write(out, crate::coordinator::report::render_csv(&res))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_kde(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    // one session serves the LSCV bandwidth search AND the final
    // density pass — a single tree build end to end
    let session = session_for(cfg, &ds);
    let h = pick_h_star(cfg, &session)?;
    let values = if cfg.kernel.is_gaussian() {
        let resolved = session.resolve(&EvalRequest::kde(h, cfg.epsilon).with_method(cfg.method));
        let dens = crate::kde::density_at_points_session(&session, h, cfg.epsilon, cfg.method)
            .map_err(|e| anyhow!("{e}"))?;
        println!(
            "dataset={} n={} D={} h={h:.6} method={}({}) mean_density={:.6e}",
            ds.name,
            ds.len(),
            ds.dim(),
            cfg.method.name(),
            resolved.name(),
            crate::util::stats::mean(&dens)
        );
        dens
    } else {
        // non-Gaussian kernels report raw kernel sums (the KDE
        // normalization constant is Gaussian-specific) plus the SoG
        // certificate trail
        let req = EvalRequest::kde(h, cfg.epsilon).with_method(cfg.method);
        let ev = session.evaluate(&req).map_err(|e| anyhow!("{e}"))?;
        let report = ev.sog.as_ref().expect("non-Gaussian answers carry a SoG report");
        println!(
            "dataset={} n={} D={} kernel={} scale={h:.6} method={}({}) components={} \
             decomp_err={:.2e} mean_sum={:.6e}",
            ds.name,
            ds.len(),
            ds.dim(),
            cfg.kernel,
            cfg.method.name(),
            ev.method.name(),
            report.components.len(),
            report.decomp_err,
            crate::util::stats::mean(&ev.sums)
        );
        ev.sums
    };
    if let Some(out) = &cfg.out {
        let mut rows = Vec::with_capacity(values.len());
        for (i, d) in values.iter().enumerate() {
            let mut row = ds.points.row(i).to_vec();
            row.push(*d);
            rows.push(row);
        }
        data::csv::save(std::path::Path::new(out), &crate::geometry::Matrix::from_rows(&rows))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_datagen(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let out = cfg.out.clone().unwrap_or_else(|| format!("{}.csv", ds.name));
    data::csv::save(std::path::Path::new(&out), &ds.points)?;
    println!("wrote {out}: {} × {}", ds.len(), ds.dim());
    Ok(())
}

fn cmd_selftest(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let session = session_for(cfg, &ds);
    let pilot = if cfg.bandwidth > 0.0 { cfg.bandwidth } else { silverman(&ds.points) };
    let mut ok = true;
    if cfg.kernel.is_gaussian() {
        for mult in [1e-2, 1.0, 1e2] {
            let h = pilot * mult;
            let (exact, _, _) =
                session.exact_sums(h, cfg.epsilon).map_err(|e| anyhow!("truth at h={h}: {e}"))?;
            let mut methods = vec![Method::Dfd, Method::Dfdo, Method::Dfto, Method::Dito];
            if ds.dim() >= 10 && mult >= 1.0 {
                // high-dim non-near-diagonal regime: exercise the
                // sliced Fourier engine where it is actually routed
                methods.push(Method::Sliced);
            }
            methods.push(Method::Auto);
            for m in methods {
                let req = EvalRequest::kde(h, cfg.epsilon).with_method(m);
                let res = match session.evaluate(&req) {
                    Ok(res) => res,
                    // X/∞ are the paper's recorded verdicts, not
                    // harness failures: the engine refused to answer
                    // rather than answering wrong
                    Err(e @ (AlgoError::RamExhausted(_) | AlgoError::ToleranceUnreachable(_))) => {
                        println!("{:<12} h={h:<12.5} {e}", m.name());
                        continue;
                    }
                    Err(err) => return Err(anyhow!("{}: {err}", m.name())),
                };
                let rel = max_relative_error(&res.sums, &exact);
                let pass = rel <= cfg.epsilon * (1.0 + 1e-9);
                ok &= pass;
                let label = if m == Method::Auto {
                    format!("Auto({})", res.method.name())
                } else {
                    m.name().to_string()
                };
                println!(
                    "{label:<12} h={h:<12.5} rel_err={rel:.2e}  {}",
                    if pass { "OK" } else { "FAIL" }
                );
            }
        }
    } else {
        // SoG guarantee is absolute scaled by the total weight W:
        // max_q |G̃(q) − G(q)| ≤ ε·W.  Tree methods only — Naive
        // per-component would be O(terms·N²).
        let w = session.total_weight();
        for mult in [1e-2, 1.0, 1e2] {
            let h = pilot * mult;
            let (exact, _, _) = session
                .exact_kernel_sums(cfg.kernel, h, cfg.epsilon)
                .map_err(|e| anyhow!("{} truth at h={h}: {e}", cfg.kernel))?;
            for m in [Method::Dfdo, Method::Dito, Method::Auto] {
                let req = EvalRequest::kde(h, cfg.epsilon).with_method(m);
                let res =
                    session.evaluate(&req).map_err(|err| anyhow!("{}: {err}", m.name()))?;
                let err = max_weight_scaled_error(&res.sums, &exact, w);
                let pass = err <= cfg.epsilon * (1.0 + 1e-9);
                ok &= pass;
                let comps = res.sog.as_ref().map_or(0, |r| r.components.len());
                println!(
                    "{:<12} kernel={} h={h:<12.5} components={comps} scaled_err={err:.2e}  {}",
                    m.name(),
                    cfg.kernel,
                    if pass { "OK" } else { "FAIL" }
                );
            }
        }
    }
    if !ok {
        bail!("selftest FAILED");
    }
    println!("selftest OK");
    Ok(())
}

fn cmd_runtime(cfg: &RunConfig) -> Result<()> {
    let ds = load_dataset(cfg)?;
    let tiled = crate::runtime::TiledNaive::load(ds.dim())?;
    let h = silverman(&ds.points);
    let p = GaussSumProblem::kde(&ds.points, h, cfg.epsilon);
    let (pjrt, pjrt_secs) = crate::util::timer::time_it(|| tiled.run(&p).unwrap());
    let (rust, rust_secs) = crate::util::timer::time_it(|| Naive::new().run(&p).unwrap());
    let rel = max_relative_error(&pjrt.sums, &rust.sums);
    println!(
        "{} D={}: rel_err vs rust naive = {rel:.2e}  ({} {:.3}s, rust {:.3}s)",
        tiled.name(),
        ds.dim(),
        if tiled.is_cpu_fallback() { "cpu-fallback" } else { "pjrt" },
        pjrt_secs,
        rust_secs
    );
    if rel > 1e-9 {
        bail!("runtime mismatch");
    }
    println!("runtime OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(run(&["bogus".to_string()]).is_err());
    }

    #[test]
    fn no_args_prints_usage() {
        assert!(run(&[]).is_ok());
    }

    #[test]
    fn selftest_small() {
        let args: Vec<String> = ["selftest", "--n", "200", "--dataset", "astro2d"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        run(&args).unwrap();
    }

    #[test]
    fn selftest_high_dim_runs_the_sliced_engine() {
        // hyper20 + pinned large bandwidth: the Sliced rows at the
        // ×1 and ×100 multipliers must verify (or print the paper's
        // X/∞ verdict) without failing the harness; the dual-tree
        // rows keep their ε checks as on every other dataset
        let args: Vec<String> =
            ["selftest", "--n", "120", "--dataset", "hyper20", "--h", "4"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn selftest_with_fast_exp_off_uses_bit_exact_path() {
        // --fast-exp false must thread through config → session →
        // DualTreeConfig and still pass every engine's ε check
        let args: Vec<String> =
            ["selftest", "--n", "150", "--dataset", "astro2d", "--fast-exp", "false"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn selftest_with_simd_off_pins_the_scalar_table() {
        // --simd off must thread through config → session →
        // DualTreeConfig and still pass every engine's ε check
        let args: Vec<String> =
            ["selftest", "--n", "150", "--dataset", "astro2d", "--simd", "off"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn selftest_with_f32_precision_stays_eps_verified() {
        // --precision f32 engages the mixed-precision tile where its
        // certificate fits ε/4 and demotes elsewhere; either way the
        // selftest's rel-err checks must hold
        let args: Vec<String> =
            ["selftest", "--n", "150", "--dataset", "astro2d", "--precision", "f32"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn simd_flag_rejects_unknown_name() {
        let args: Vec<String> =
            ["selftest", "--simd", "avx512"].iter().map(|s| s.to_string()).collect();
        let err = run(&args).unwrap_err().to_string();
        assert!(err.contains("auto") && err.contains("off"), "{err}");
    }

    #[test]
    fn selftest_with_laplace_kernel() {
        // --kernel laplace routes through the SoG layer end to end:
        // decomposition fit, ε split, pooled component batch, and the
        // weight-scaled guarantee check against the exact Laplace sums
        let args: Vec<String> =
            ["selftest", "--n", "200", "--dataset", "astro2d", "--kernel", "laplace"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn kernel_flag_rejects_unknown_name() {
        let args: Vec<String> =
            ["selftest", "--kernel", "cauchy"].iter().map(|s| s.to_string()).collect();
        let err = run(&args).unwrap_err().to_string();
        assert!(err.contains("matern32") && err.contains("imq"), "{err}");
    }

    #[test]
    fn kde_with_auto_method_end_to_end() {
        // --method auto exercises Session + cost-model resolution +
        // LSCV through the batch API, end to end from the CLI
        let args: Vec<String> =
            ["kde", "--n", "200", "--dataset", "astro2d", "--method", "auto"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn kde_rejects_unknown_method_with_listing() {
        let args: Vec<String> =
            ["kde", "--method", "bogus"].iter().map(|s| s.to_string()).collect();
        let err = run(&args).unwrap_err().to_string();
        assert!(err.contains("auto") && err.contains("dito"), "{err}");
    }

    #[test]
    fn datagen_writes_csv() {
        let out = std::env::temp_dir().join("fg_cli_datagen.csv");
        let args: Vec<String> = [
            "datagen",
            "--n",
            "50",
            "--dataset",
            "bio5",
            "--out",
            out.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(&args).unwrap();
        let m = data::csv::load(&out).unwrap();
        assert_eq!(m.rows(), 50);
        assert_eq!(m.cols(), 5);
    }
}
