//! Micro-benchmarks of the computational primitives the profiles point
//! at: Hermite recurrences, monomial evaluation, the three translation
//! operators, moment accumulation, the exhaustive base-case loop, and
//! one PJRT chunk execution. These are the EXPERIMENTS.md §Perf
//! instruments.
//!
//! Run: `cargo bench --bench kernels`

use fastgauss::geometry::Matrix;
use fastgauss::hermite::{
    accumulate_farfield, eval_farfield, h2h, h2l, l2l, HermiteTable, PairTable,
};
use fastgauss::kernel::GaussianKernel;
use fastgauss::multiindex::{Layout, MultiIndexSet};
use fastgauss::util::timer::time_it;
use fastgauss::util::Pcg32;

/// Time `iters` runs of `f`, report ns/op.
fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.min(10) {
        f();
    }
    let ((), secs) = time_it(|| {
        for _ in 0..iters {
            f();
        }
    });
    println!("{name:<44} {:>12.1} ns/op   ({iters} iters)", secs * 1e9 / iters as f64);
}

fn main() {
    println!("== primitive micro-benchmarks ==");
    let mut rng = Pcg32::new(7);

    // Hermite recurrence
    let mut out16 = vec![0.0; 17];
    bench("hermite_values_into(order 16)", 1_000_000, || {
        fastgauss::hermite::univariate::hermite_values_into(0.73, &mut out16);
        std::hint::black_box(&out16);
    });

    for (label, layout, d, p) in [
        ("graded D=2 p=8 (36 idx)", Layout::Graded, 2usize, 8usize),
        ("graded D=5 p=4 (70 idx)", Layout::Graded, 5, 4),
        ("grid   D=2 p=8 (64 idx)", Layout::Grid, 2, 8),
        ("grid   D=5 p=4 (1024 idx)", Layout::Grid, 5, 4),
    ] {
        let set = MultiIndexSet::new(layout, d, p);
        let pairs = PairTable::new(&set);
        let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let mut mono = vec![0.0; set.len()];
        bench(&format!("monomials {label}"), 200_000, || {
            set.eval_monomials(&x, &mut mono);
            std::hint::black_box(&mono);
        });

        let coeffs: Vec<f64> = (0..set.len()).map(|_| rng.uniform()).collect();
        let mut dst = vec![0.0; set.len()];
        let c0 = vec![0.2; d];
        let c1 = vec![0.0; d];
        let mut off = vec![0.0; d];
        let mut table = HermiteTable::new(d, 2 * p);
        bench(&format!("h2h       {label}"), 2_000, || {
            h2h(&set, &pairs, &coeffs, &c0, &c1, 1.0, &mut dst, &mut mono, &mut off);
            std::hint::black_box(&dst);
        });
        bench(&format!("l2l       {label}"), 2_000, || {
            l2l(&set, &pairs, &coeffs, &c0, &c1, 1.0, &mut dst, &mut mono, &mut off);
            std::hint::black_box(&dst);
        });
        bench(&format!("h2l       {label}"), 2_000, || {
            h2l(&set, &coeffs, &c0, &c1, 1.0, &mut dst, &mut table, &mut off);
            std::hint::black_box(&dst);
        });

        // moment accumulation + far-field evaluation over 32 points
        let pts = Matrix::from_rows(
            &(0..32)
                .map(|_| (0..d).map(|_| rng.uniform()).collect::<Vec<f64>>())
                .collect::<Vec<_>>(),
        );
        let w = vec![1.0; 32];
        let all: Vec<usize> = (0..32).collect();
        bench(&format!("accum_ff/32pt {label}"), 5_000, || {
            dst.iter_mut().for_each(|v| *v = 0.0);
            accumulate_farfield(&set, &pts, &all, &w, &c0, 1.0, &mut dst, &mut mono, &mut off);
            std::hint::black_box(&dst);
        });
        let xq: Vec<f64> = (0..d).map(|_| rng.uniform()).collect();
        bench(&format!("eval_ff       {label}"), 50_000, || {
            let v = eval_farfield(&set, &coeffs, &c0, 1.0, &xq, &mut table, &mut off);
            std::hint::black_box(v);
        });
    }

    // the exp that dominates base cases: libm vs the certified block poly
    let args: Vec<f64> = (0..256).map(|i| -(i as f64) * 0.11 - 0.01).collect();
    let mut buf = vec![0.0; 256];
    bench("libm exp ×256", 50_000, || {
        buf.copy_from_slice(&args);
        for v in buf.iter_mut() {
            *v = v.exp();
        }
        std::hint::black_box(&buf);
    });
    bench("fastexp::exp_block ×256", 50_000, || {
        buf.copy_from_slice(&args);
        fastgauss::compute::fastexp::exp_block(&mut buf);
        std::hint::black_box(&buf);
    });

    // base-case kernel loop: 32×32 points, D=5
    let d = 5;
    let kernel = GaussianKernel::new(0.3);
    let q = Matrix::from_rows(
        &(0..32).map(|_| (0..d).map(|_| rng.uniform()).collect::<Vec<f64>>()).collect::<Vec<_>>(),
    );
    let r = q.clone();
    bench("base case 32x32 D=5", 20_000, || {
        let mut acc = 0.0;
        for i in 0..32 {
            let qi = q.row(i);
            for j in 0..32 {
                acc += kernel.eval_sq(fastgauss::geometry::sqdist(qi, r.row(j)));
            }
        }
        std::hint::black_box(acc);
    });

    // one PJRT chunk (256 queries × 4096 refs)
    if cfg!(feature = "pjrt")
        && fastgauss::runtime::artifacts_dir().join("manifest.json").exists()
    {
        let exec =
            fastgauss::runtime::TileExecutor::load(&fastgauss::runtime::artifacts_dir(), 5)
                .unwrap();
        let qm = Matrix::from_rows(
            &(0..256)
                .map(|_| (0..d).map(|_| rng.uniform()).collect::<Vec<f64>>())
                .collect::<Vec<_>>(),
        );
        let rm = Matrix::from_rows(
            &(0..4096)
                .map(|_| (0..d).map(|_| rng.uniform()).collect::<Vec<f64>>())
                .collect::<Vec<_>>(),
        );
        let w = vec![1.0; 4096];
        bench("pjrt chunk 256x4096 D=5", 20, || {
            let v = exec.gauss_sum(&qm, &rm, &w, 0.3).unwrap();
            std::hint::black_box(v);
        });
    } else {
        println!("(artifacts not built — skipping PJRT micro-bench)");
    }
}
