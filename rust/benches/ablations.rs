//! Ablation benches for the design choices DESIGN.md calls out:
//!   §tokens  — token error control on/off (DFD vs DFDO), isolating the
//!              paper's Section-5 contribution;
//!   §layout  — O(Dᵖ) vs O(pᴰ) expansion at fixed control (DITO vs DFTO);
//!   §leaf    — tree leaf size;
//!   §plimit  — truncation-order cap;
//!   §tile    — PJRT-artifact base kernel vs pure-rust base case on the
//!              exhaustive path (when does offload pay?).
//!
//! Run: `cargo bench --bench ablations` (knobs: FASTGAUSS_N)

use fastgauss::algo::dualtree::{run_dualtree, DualTreeConfig, SeriesKind};
use fastgauss::algo::{naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::util::timer::time_it;

fn median_secs<F: FnMut() -> ()>(mut f: F, reps: usize) -> f64 {
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let ((), s) = time_it(&mut f);
            s
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn main() {
    let n: usize =
        std::env::var("FASTGAUSS_N").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    let eps = 0.01;
    println!("== ablations, N = {n}, eps = {eps} ==\n");

    // ---- §tokens: DFD vs DFDO across dims and bandwidth multipliers ----
    println!("§tokens — FD-only engine, token ledger off/on (secs, median of 3)");
    println!("{:<10} {:>6} {:>10} {:>10} {:>8}", "dataset", "h/h*", "DFD", "DFDO", "ratio");
    for name in ["astro2d", "pall7", "covtype10"] {
        let ds = data::by_name(name, n, 42).unwrap();
        let hstar = silverman(&ds.points);
        for mult in [1.0, 100.0] {
            let problem = GaussSumProblem::kde(&ds.points, hstar * mult, eps);
            let off = DualTreeConfig { use_tokens: false, series: None, ..Default::default() };
            let on = DualTreeConfig { use_tokens: true, series: None, ..Default::default() };
            let t_off = median_secs(|| drop(run_dualtree(&problem, &off).unwrap()), 3);
            let t_on = median_secs(|| drop(run_dualtree(&problem, &on).unwrap()), 3);
            println!(
                "{name:<10} {mult:>6} {t_off:>10.4} {t_on:>10.4} {:>8.3}",
                t_on / t_off
            );
        }
    }

    // ---- §layout: graded O(D^p) vs grid O(p^D) series ----
    println!("\n§layout — expansion family at fixed token control");
    println!("{:<10} {:>6} {:>10} {:>10}", "dataset", "h/h*", "OpdGrid", "OdpGraded");
    for name in ["astro2d", "galaxy3d", "bio5"] {
        let ds = data::by_name(name, n, 42).unwrap();
        let hstar = silverman(&ds.points);
        for mult in [1.0, 100.0] {
            let problem = GaussSumProblem::kde(&ds.points, hstar * mult, eps);
            let grid =
                DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() };
            let graded =
                DualTreeConfig { series: Some(SeriesKind::OdpGraded), ..Default::default() };
            let t_grid = median_secs(|| drop(run_dualtree(&problem, &grid).unwrap()), 3);
            let t_graded = median_secs(|| drop(run_dualtree(&problem, &graded).unwrap()), 3);
            println!("{name:<10} {mult:>6} {t_grid:>10.4} {t_graded:>10.4}");
        }
    }

    // ---- §leaf: base-case granularity ----
    println!("\n§leaf — leaf size (astro2d, h = h*)");
    let ds = data::by_name("astro2d", n, 42).unwrap();
    let hstar = silverman(&ds.points);
    let problem = GaussSumProblem::kde(&ds.points, hstar, eps);
    print!("leaf:");
    for leaf in [8, 16, 32, 64, 128] {
        let cfg = DualTreeConfig { leaf_size: leaf, ..Default::default() };
        let t = median_secs(|| drop(run_dualtree(&problem, &cfg).unwrap()), 3);
        print!("  {leaf}={t:.4}s");
    }
    println!();

    // ---- §plimit: truncation-order cap (2-D, large h where series rule) ----
    println!("\n§plimit — order cap (astro2d, h = 100·h*)");
    let problem_big = GaussSumProblem::kde(&ds.points, hstar * 100.0, eps);
    print!("plimit:");
    for p in [1, 2, 4, 6, 8] {
        let cfg = DualTreeConfig { plimit: Some(p), ..Default::default() };
        let t = median_secs(|| drop(run_dualtree(&problem_big, &cfg).unwrap()), 3);
        print!("  {p}={t:.4}s");
    }
    println!();

    // ---- §tile: PJRT artifact vs pure-rust exhaustive path ----
    println!("\n§tile — exhaustive path: rust loops vs PJRT artifact (one run)");
    if fastgauss::runtime::artifacts_dir().join("manifest.json").exists() {
        for name in ["astro2d", "texture16"] {
            let ds = data::by_name(name, n, 42).unwrap();
            let h = silverman(&ds.points);
            let problem = GaussSumProblem::kde(&ds.points, h, eps);
            let (_, t_rust) = time_it(|| Naive::new().run(&problem).unwrap());
            let tiled = fastgauss::runtime::TiledNaive::load(ds.dim()).unwrap();
            let (_, t_warm) = time_it(|| tiled.run(&problem).unwrap()); // compile+exec
            let (_, t_pjrt) = time_it(|| tiled.run(&problem).unwrap());
            println!(
                "{name:<10} rust={t_rust:.3}s  pjrt(first)={t_warm:.3}s  pjrt(warm)={t_pjrt:.3}s"
            );
        }
    } else {
        println!("(artifacts not built — run `make artifacts`)");
    }
}
