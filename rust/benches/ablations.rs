//! Ablation benches for the design choices DESIGN.md calls out:
//!   §tokens  — token error control on/off (DFD vs DFDO), isolating the
//!              paper's Section-5 contribution;
//!   §layout  — O(Dᵖ) vs O(pᴰ) expansion at fixed control (DITO vs DFTO);
//!   §leaf    — tree leaf size;
//!   §plimit  — truncation-order cap;
//!   §tile    — PJRT-artifact base kernel vs pure-rust base case on the
//!              exhaustive path (when does offload pay?);
//!   §sweep   — the amortization claim: a 13-point LSCV-style
//!              bandwidth sweep via per-h rebuilds (sequential) vs one
//!              prepared multi-threaded Session (evaluate_batch over
//!              the grid), verified against Naive at every grid point;
//!   §basecase — the base-case ladder on galaxy3d: old scalar triple
//!              loop vs SoA microkernel vs the PR-4 tiled fast path
//!              (cached norms + dot tiles + certified exp_block; see
//!              also `cargo run --release --bin bench_json` for the
//!              machine-readable old-vs-tiled trajectory).
//!
//! Run: `cargo bench --bench ablations`
//! (knobs: FASTGAUSS_N, FASTGAUSS_SWEEP_N)

use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::algo::dualtree::{run_dualtree, DualTreeConfig, SeriesKind};
use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::compute;
use fastgauss::data;
use fastgauss::kde::bandwidth::{log_grid, silverman};
use fastgauss::util::timer::time_it;

fn median_secs<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let ((), s) = time_it(&mut f);
            s
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn main() {
    let n: usize =
        std::env::var("FASTGAUSS_N").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    let eps = 0.01;
    println!("== ablations, N = {n}, eps = {eps} ==\n");

    // ---- §tokens: DFD vs DFDO across dims and bandwidth multipliers ----
    println!("§tokens — FD-only engine, token ledger off/on (secs, median of 3)");
    println!("{:<10} {:>6} {:>10} {:>10} {:>8}", "dataset", "h/h*", "DFD", "DFDO", "ratio");
    for name in ["astro2d", "pall7", "covtype10"] {
        let ds = data::by_name(name, n, 42).unwrap();
        let hstar = silverman(&ds.points);
        for mult in [1.0, 100.0] {
            let problem = GaussSumProblem::kde(&ds.points, hstar * mult, eps);
            let off = DualTreeConfig { use_tokens: false, series: None, ..Default::default() };
            let on = DualTreeConfig { use_tokens: true, series: None, ..Default::default() };
            let t_off = median_secs(|| drop(run_dualtree(&problem, &off).unwrap()), 3);
            let t_on = median_secs(|| drop(run_dualtree(&problem, &on).unwrap()), 3);
            println!(
                "{name:<10} {mult:>6} {t_off:>10.4} {t_on:>10.4} {:>8.3}",
                t_on / t_off
            );
        }
    }

    // ---- §layout: graded O(D^p) vs grid O(p^D) series ----
    println!("\n§layout — expansion family at fixed token control");
    println!("{:<10} {:>6} {:>10} {:>10}", "dataset", "h/h*", "OpdGrid", "OdpGraded");
    for name in ["astro2d", "galaxy3d", "bio5"] {
        let ds = data::by_name(name, n, 42).unwrap();
        let hstar = silverman(&ds.points);
        for mult in [1.0, 100.0] {
            let problem = GaussSumProblem::kde(&ds.points, hstar * mult, eps);
            let grid =
                DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() };
            let graded =
                DualTreeConfig { series: Some(SeriesKind::OdpGraded), ..Default::default() };
            let t_grid = median_secs(|| drop(run_dualtree(&problem, &grid).unwrap()), 3);
            let t_graded = median_secs(|| drop(run_dualtree(&problem, &graded).unwrap()), 3);
            println!("{name:<10} {mult:>6} {t_grid:>10.4} {t_graded:>10.4}");
        }
    }

    // ---- §leaf: base-case granularity ----
    println!("\n§leaf — leaf size (astro2d, h = h*)");
    let ds = data::by_name("astro2d", n, 42).unwrap();
    let hstar = silverman(&ds.points);
    let problem = GaussSumProblem::kde(&ds.points, hstar, eps);
    print!("leaf:");
    for leaf in [8, 16, 32, 64, 128] {
        let cfg = DualTreeConfig { leaf_size: leaf, ..Default::default() };
        let t = median_secs(|| drop(run_dualtree(&problem, &cfg).unwrap()), 3);
        print!("  {leaf}={t:.4}s");
    }
    println!();

    // ---- §plimit: truncation-order cap (2-D, large h where series rule) ----
    println!("\n§plimit — order cap (astro2d, h = 100·h*)");
    let problem_big = GaussSumProblem::kde(&ds.points, hstar * 100.0, eps);
    print!("plimit:");
    for p in [1, 2, 4, 6, 8] {
        let cfg = DualTreeConfig { plimit: Some(p), ..Default::default() };
        let t = median_secs(|| drop(run_dualtree(&problem_big, &cfg).unwrap()), 3);
        print!("  {p}={t:.4}s");
    }
    println!();

    // ---- §sweep: bandwidth-sweep amortization + threading ----
    let n_sweep: usize = std::env::var("FASTGAUSS_SWEEP_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "\n§sweep — 13-point bandwidth sweep, astro2d N={n_sweep} (DITO, {threads} threads)"
    );
    let ds_sweep = data::by_name("astro2d", n_sweep, 42).unwrap();
    let hstar_sweep = silverman(&ds_sweep.points);
    let grid = log_grid(hstar_sweep, 1e-2, 1e2, 13);
    let cfg_sweep = DualTreeConfig::default();

    // baseline: sequential, one tree build per grid point
    let (rebuild_sums, t_rebuild) = time_it(|| {
        grid.iter()
            .map(|&h| {
                let p = GaussSumProblem::kde(&ds_sweep.points, h, eps);
                run_dualtree(&p, &cfg_sweep).unwrap().sums
            })
            .collect::<Vec<_>>()
    });

    // session: one tree build for the whole grid, parallel across the
    // batched requests (the front door every caller now uses)
    let (session, t_prep) = time_it(|| {
        Session::prepare(&ds_sweep.points, PrepareOptions { threads, ..Default::default() })
    });
    let reqs: Vec<EvalRequest<'static>> =
        grid.iter().map(|&h| EvalRequest::kde(h, eps).with_method(Method::Dito)).collect();
    let (engine_results, t_eval) = time_it(|| {
        session
            .evaluate_batch(&reqs)
            .into_iter()
            .map(|r| r.unwrap())
            .collect::<Vec<_>>()
    });
    assert_eq!(session.tree_builds(), 1, "session must build the tree exactly once");
    let t_engine = t_prep + t_eval;

    // verify every grid point against exhaustive truth
    let mut worst = 0.0f64;
    for (i, &h) in grid.iter().enumerate() {
        let p = GaussSumProblem::kde(&ds_sweep.points, h, eps);
        let exact = Naive::new().run(&p).unwrap().sums;
        let rel = max_relative_error(&engine_results[i].sums, &exact);
        assert!(
            rel <= eps * (1.0 + 1e-9),
            "grid point {i} (h={h:.4e}): rel {rel:.2e} > eps"
        );
        worst = worst.max(rel.max(max_relative_error(&rebuild_sums[i], &exact)));
    }
    println!(
        "rebuild×13 = {t_rebuild:.3}s   session(prep {t_prep:.3}s + eval {t_eval:.3}s) = \
         {t_engine:.3}s   speedup = {:.2}x   worst rel_err = {worst:.2e} (ε = {eps})",
        t_rebuild / t_engine
    );

    // ---- §basecase: SoA microkernel vs scalar base case ----
    // galaxy3d at the default ε of this harness; this is the leaf-leaf
    // workload that dominates dual-tree time at tight ε, isolated.
    let nb = n.min(4000);
    println!(
        "\n§basecase — compute microkernel vs scalar triple loop (galaxy3d N={nb}, ε = {eps})"
    );
    let ds_base = data::by_name("galaxy3d", nb, 42).unwrap();
    let h_base = silverman(&ds_base.points);
    let kernel = fastgauss::kernel::GaussianKernel::new(h_base);
    let w_base = vec![1.0; nb];
    let mut out_scalar = vec![0.0; nb];
    let mut out_micro = vec![0.0; nb];
    let t_scalar = median_secs(
        || {
            out_scalar.fill(0.0);
            compute::reference::scalar_gauss_sums(
                &ds_base.points,
                &ds_base.points,
                &w_base,
                &kernel,
                &mut out_scalar,
            );
        },
        3,
    );
    let mut scratch = compute::Scratch::with_block(ds_base.dim(), compute::BLOCK);
    let t_micro = median_secs(
        || {
            out_micro.fill(0.0);
            compute::gauss_sum_all(
                &ds_base.points,
                &ds_base.points,
                &w_base,
                &kernel,
                compute::BLOCK,
                &mut scratch,
                &mut out_micro,
            );
        },
        3,
    );
    let mut worst_dev = 0.0f64;
    for i in 0..nb {
        worst_dev = worst_dev.max((out_micro[i] - out_scalar[i]).abs() / out_scalar[i].max(1.0));
    }
    assert!(worst_dev <= 1e-12, "microkernel diverged from scalar: {worst_dev:.2e}");
    // the PR-4 tiled fast path: norms trick + certified exp_block
    let mut out_tiled = vec![0.0; nb];
    let t_tiled = median_secs(
        || {
            out_tiled.fill(0.0);
            compute::gauss_sum_all_fast(
                &ds_base.points,
                &ds_base.points,
                &w_base,
                &kernel,
                compute::BLOCK,
                &mut scratch,
                &mut out_tiled,
            );
        },
        3,
    );
    let mut worst_fast = 0.0f64;
    for i in 0..nb {
        worst_fast = worst_fast.max((out_tiled[i] - out_scalar[i]).abs() / out_scalar[i].max(1.0));
    }
    assert!(worst_fast <= 1e-11, "tiled fast path out of certified range: {worst_fast:.2e}");
    println!(
        "scalar={t_scalar:.4}s  microkernel={t_micro:.4}s ({:.2}x)  \
         tiled+fastexp={t_tiled:.4}s ({:.2}x)  max rel dev: micro={worst_dev:.1e} tiled={worst_fast:.1e}",
        t_scalar / t_micro,
        t_scalar / t_tiled
    );

    // ---- §tile: PJRT artifact vs pure-rust exhaustive path ----
    println!("\n§tile — exhaustive path: rust loops vs PJRT artifact (one run)");
    if cfg!(feature = "pjrt")
        && fastgauss::runtime::artifacts_dir().join("manifest.json").exists()
    {
        for name in ["astro2d", "texture16"] {
            let ds = data::by_name(name, n, 42).unwrap();
            let h = silverman(&ds.points);
            let problem = GaussSumProblem::kde(&ds.points, h, eps);
            let (_, t_rust) = time_it(|| Naive::new().run(&problem).unwrap());
            let tiled = fastgauss::runtime::TiledNaive::load(ds.dim()).unwrap();
            let (_, t_warm) = time_it(|| tiled.run(&problem).unwrap()); // compile+exec
            let (_, t_pjrt) = time_it(|| tiled.run(&problem).unwrap());
            println!(
                "{name:<10} rust={t_rust:.3}s  pjrt(first)={t_warm:.3}s  \
                 pjrt(warm)={t_pjrt:.3}s"
            );
        }
    } else if cfg!(not(feature = "pjrt")) {
        // the tiled runtime degrades to the CPU microkernel fallback,
        // which is bit-identical to Naive — timing the pair against
        // each other would be a self-comparison, so just prove the
        // path works
        let ds = data::by_name("astro2d", n.min(2000), 42).unwrap();
        let problem = GaussSumProblem::kde(&ds.points, silverman(&ds.points), eps);
        let tiled = fastgauss::runtime::TiledNaive::load(ds.dim()).unwrap();
        let (out, t_tiled) = time_it(|| tiled.run(&problem).unwrap());
        let exact = Naive::new().run(&problem).unwrap();
        assert_eq!(out.sums, exact.sums, "CPU fallback must equal Naive bitwise");
        println!(
            "(no pjrt feature: {} ran the CPU microkernel fallback in {t_tiled:.3}s, \
             bit-identical to Naive — build with --features pjrt for the offload numbers)",
            tiled.name()
        );
    } else {
        println!("(pjrt feature on but artifacts not built — run `make artifacts`)");
    }
}
