//! Regenerates ALL SIX of the paper's evaluation tables (the paper's
//! entire results section): six datasets × seven algorithms × seven
//! bandwidths, times in seconds with verified ε = 0.01 and the X/∞
//! conventions. Each table runs on one prepared `api::Session` inside
//! `coordinator::run_sweep` (one tree build; truth computed inside the
//! worker pool and shared by every cell's verification).
//!
//! Scale knobs (1-vCPU default keeps the full run in minutes):
//!   FASTGAUSS_N=5000        points per dataset (paper: 50000)
//!   FASTGAUSS_FULL=1        shorthand for N = 50000
//!   FASTGAUSS_DATASETS=a,b  subset of datasets
//!   FASTGAUSS_OUT=dir       also write per-table CSVs
//!
//! Run: `cargo bench --bench paper_tables`

use fastgauss::api::{Precision, SimdMode};
use fastgauss::coordinator::{report, run_sweep, AlgoSpec, SweepConfig};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kernel::Kernel;

fn main() {
    let n: usize = if std::env::var("FASTGAUSS_FULL").is_ok_and(|v| v == "1") {
        50_000
    } else {
        std::env::var("FASTGAUSS_N").ok().and_then(|v| v.parse().ok()).unwrap_or(5_000)
    };
    let subset: Option<Vec<String>> = std::env::var("FASTGAUSS_DATASETS")
        .ok()
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect());
    let outdir = std::env::var("FASTGAUSS_OUT").ok();

    println!("== paper tables: N = {n}, eps = 0.01, 10^-3..10^3 × h* ==");
    println!("(paper testbed: dual Xeon 3 GHz / 2 GB; this run: {} — compare *shapes*, not seconds)\n",
             std::env::var("HOSTNAME").unwrap_or_else(|_| "this machine".into()));

    for (name, paper_name, d) in data::PAPER_SUITE {
        if let Some(only) = &subset {
            if !only.iter().any(|s| s == name) {
                continue;
            }
        }
        let ds = data::by_name(name, n, 42).unwrap();
        let h_star = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star,
            multipliers: vec![1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3],
            algorithms: AlgoSpec::paper_order(),
            workers: 1,
            leaf_size: 32,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Gaussian,
        };
        let res = run_sweep(&cfg);
        println!("--- {name} (paper: {paper_name}, D = {d}) ---");
        print!("{}", report::render_table(&res));
        println!(
            "(dual-tree prep: {:.3}s — one tree build amortized over every dual-tree cell)",
            res.prep_secs
        );
        // headline shape checks, printed so regressions are visible
        let totals = res.totals();
        let idx = |s: AlgoSpec| res.algorithms.iter().position(|a| *a == s).unwrap();
        if let (Some(dfd), Some(dito)) = (totals[idx(AlgoSpec::Dfd)], totals[idx(AlgoSpec::Dito)])
        {
            println!("shape: DITO/DFD total = {:.2}  (paper at D≤3: ≪ 1)", dito / dfd);
        }
        if let (Some(dfd), Some(dfdo)) = (totals[idx(AlgoSpec::Dfd)], totals[idx(AlgoSpec::Dfdo)])
        {
            println!("shape: DFDO/DFD total = {:.2}  (paper: ~0.85-0.95)", dfdo / dfd);
        }
        println!();
        if let Some(dir) = &outdir {
            std::fs::create_dir_all(dir).unwrap();
            let path = format!("{dir}/table_{name}.csv");
            std::fs::write(&path, report::render_csv(&res)).unwrap();
            eprintln!("wrote {path}");
        }
    }
}
