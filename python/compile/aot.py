"""AOT bridge: lower the L2 graph to HLO **text** artifacts the rust
runtime loads via the xla crate's PJRT CPU client.

Interchange is HLO text, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Outputs (one per evaluation dimension):
    artifacts/gauss_d{D}.hlo.txt
    artifacts/manifest.json   — shapes + dtype per artifact

``make artifacts`` is a no-op when inputs are unchanged (Makefile
dependency tracking), so python never runs on the request path.
"""

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.model import lower_gauss_chunk  # noqa: E402

# The paper's evaluation dimensions.
DIMS = (2, 3, 5, 7, 10, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(outdir: str, dims=DIMS) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"dtype": "f64", "artifacts": {}}
    for d in dims:
        lowered, (tq, tr, nr) = lower_gauss_chunk(d)
        text = to_hlo_text(lowered)
        name = f"gauss_d{d}.hlo.txt"
        path = os.path.join(outdir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][str(d)] = {
            "file": name,
            "dim": d,
            "tile_queries": tq,
            "block_refs": tr,
            "chunk_refs": nr,
        }
        print(f"wrote {path}: TQ={tq} TR={tr} NR={nr} ({len(text)} chars)")
    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--dims", default=",".join(map(str, DIMS)), help="comma-separated dimensions"
    )
    args = ap.parse_args()
    dims = tuple(int(x) for x in args.dims.split(","))
    build(args.out, dims)


if __name__ == "__main__":
    main()
