"""L1: the Pallas Gaussian-summation tile kernel.

This is the dense compute hot-spot every algorithm in the stack bottoms
out in: given a tile of queries Q (TQ × D), a chunk of references
R (NR × D) with weights w, and the kernel scale −1/(2h²), produce the
partial sums  G[i] = Σ_j w[j]·exp(−‖Q_i − R_j‖²/(2h²)).

TPU-shaped formulation (DESIGN.md §Hardware-Adaptation):

* the squared distance matrix is computed as
  ‖q‖² + ‖r‖² − 2·q·rᵀ — the cross term is a (TQ,D)×(D,TR) matmul that
  feeds the MXU; norms are cheap VPU reductions;
* the reference axis is blocked via the pallas grid: each grid step
  stages one (TR, D) reference block plus the (TQ, D) query tile in
  VMEM and accumulates into the (TQ,) output block, which pallas keeps
  resident across grid steps (sequential-grid revisiting);
* block sizes are chosen in `vmem_budget_blocks` so
  TQ·D + TR·D + TQ·TR + TQ doubles fit comfortably in a 16 MiB VMEM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is exactly what
the AOT bridge needs (see /opt/xla-example/README.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (f64): TQ·TR = 256·512 = 128k doubles = 1 MiB for
# the distance tile; query/ref strips are ≤ 512·16 doubles. Total VMEM
# footprint ≈ 1.2 MiB ≪ 16 MiB, leaving room for double-buffering the
# reference stream.
DEFAULT_TQ = 256
DEFAULT_TR = 512


def vmem_budget_blocks(dim: int, dtype_bytes: int = 8, budget_bytes: int = 16 * 2**20):
    """Pick (TQ, TR) so the working set fits in a VMEM budget with 4×
    headroom for double-buffering and compiler temporaries."""
    tq, tr = DEFAULT_TQ, DEFAULT_TR
    while True:
        working = dtype_bytes * (tq * dim + tr * dim + tq * tr + tq)
        if working * 4 <= budget_bytes or (tq <= 32 and tr <= 64):
            return tq, tr
        if tr >= tq:
            tr //= 2
        else:
            tq //= 2


def _tile_kernel(q_ref, r_ref, w_ref, s_ref, o_ref):
    """One grid step: accumulate this reference block's partial sums."""
    i = pl.program_id(0)
    q = q_ref[...]
    r = r_ref[...]
    w = w_ref[...]
    # ‖q−r‖² = ‖q‖² + ‖r‖² − 2 q·rᵀ  (cross term → MXU matmul)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    rn = jnp.sum(r * r, axis=1)[None, :]
    d2 = qn + rn - 2.0 * (q @ r.T)
    # clamp tiny negatives from cancellation before exp
    d2 = jnp.maximum(d2, 0.0)
    part = jnp.exp(d2 * s_ref[0]) @ w

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


@partial(jax.jit, static_argnames=("tr",))
def gauss_tile(q, r, w, neg_inv_2h2, *, tr: int = DEFAULT_TR):
    """Pallas-blocked Gaussian tile summation.

    Args:
      q: (TQ, D) queries.
      r: (NR, D) references; NR must be a multiple of ``tr``.
      w: (NR,) weights (zero-padded rows contribute nothing).
      neg_inv_2h2: (1,) array holding −1/(2h²).
      tr: reference block size.

    Returns:
      (TQ,) partial sums over this reference chunk.
    """
    tq, d = q.shape
    nr = r.shape[0]
    assert nr % tr == 0, f"NR={nr} not a multiple of TR={tr}"
    return pl.pallas_call(
        _tile_kernel,
        grid=(nr // tr,),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i: (0, 0)),
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((tr,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tq,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((tq,), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, r, w, neg_inv_2h2)
