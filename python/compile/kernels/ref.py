"""Pure-jnp oracle for the Pallas tile kernel — the build-time
correctness signal. Deliberately written in the most obvious O(TQ·NR·D)
broadcast form, with none of the kernel's blocking or algebraic
rearrangement, so the two implementations share no structure."""

import jax.numpy as jnp


def gauss_tile_ref(q, r, w, neg_inv_2h2):
    """Reference Gaussian tile summation.

    G[i] = Σ_j w[j] · exp(neg_inv_2h2 · ‖q_i − r_j‖²)
    """
    diff = q[:, None, :] - r[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.exp(d2 * neg_inv_2h2[0]) @ w


def gauss_sum_ref(q, r, w, h):
    """Bandwidth-form convenience wrapper."""
    s = jnp.asarray([-0.5 / (h * h)], dtype=q.dtype)
    return gauss_tile_ref(q, r, w, s)
