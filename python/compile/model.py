"""L2: the JAX compute graph the rust runtime executes.

The "model" for a Gaussian-summation system is the chunked exhaustive
summation graph: one artifact evaluates a fixed-shape query tile against
a fixed-shape reference chunk by calling the L1 Pallas kernel, and the
rust coordinator streams tiles/chunks through it (padding with
zero-weight rows). Keeping the artifact shape fixed is what lets the HLO
be compiled once per dimension and reused for every dataset size.

Build-time only: this module is lowered by ``aot.py`` and never imported
at runtime.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.gauss_tile import gauss_tile, vmem_budget_blocks  # noqa: E402


def gauss_chunk(q, r, w, neg_inv_2h2, *, tr):
    """One (query tile × reference chunk) partial summation.

    Returned as a 1-tuple — the AOT bridge lowers with return_tuple=True
    and the rust side unwraps with ``to_tuple1`` (see aot.py).
    """
    return (gauss_tile(q, r, w, neg_inv_2h2, tr=tr),)


def artifact_spec(dim: int, dtype=jnp.float64):
    """Shapes for the per-dimension artifact: (TQ, TR, NR).

    NR (the reference chunk staged per execution) is 8 blocks of TR so
    each rust call amortizes dispatch overhead over a decent chunk.
    """
    tq, tr = vmem_budget_blocks(dim, dtype_bytes=dtype(0).dtype.itemsize)
    nr = 8 * tr
    return tq, tr, nr


def lower_gauss_chunk(dim: int, dtype=jnp.float64):
    """jax.jit(...).lower(...) for the D-dimensional artifact."""
    tq, tr, nr = artifact_spec(dim, dtype)
    q = jax.ShapeDtypeStruct((tq, dim), dtype)
    r = jax.ShapeDtypeStruct((nr, dim), dtype)
    w = jax.ShapeDtypeStruct((nr,), dtype)
    s = jax.ShapeDtypeStruct((1,), dtype)
    fn = lambda q, r, w, s: gauss_chunk(q, r, w, s, tr=tr)  # noqa: E731
    return jax.jit(fn).lower(q, r, w, s), (tq, tr, nr)
