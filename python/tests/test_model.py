"""L2 correctness: the chunked artifact graph vs the oracle, plus the
artifact-spec contract the rust runtime relies on."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.model import artifact_spec, gauss_chunk, lower_gauss_chunk  # noqa: E402
from compile.kernels.ref import gauss_tile_ref  # noqa: E402


@pytest.mark.parametrize("d", [2, 3, 5])
def test_chunk_matches_ref(d):
    tq, tr, nr = artifact_spec(d)
    k = jax.random.PRNGKey(d)
    kq, kr, kw = jax.random.split(k, 3)
    q = jax.random.uniform(kq, (tq, d), jnp.float64)
    r = jax.random.uniform(kr, (nr, d), jnp.float64)
    w = jax.random.uniform(kw, (nr,), jnp.float64)
    s = jnp.asarray([-0.5 / 0.09])
    (got,) = gauss_chunk(q, r, w, s, tr=tr)
    want = gauss_tile_ref(q, r, w, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)


def test_padding_with_zero_weights_is_exact():
    # the rust runtime pads queries and references; padded rows must not
    # perturb real outputs
    d = 3
    tq, tr, nr = artifact_spec(d)
    k = jax.random.PRNGKey(0)
    q_real = jax.random.uniform(k, (5, d), jnp.float64)
    q = jnp.zeros((tq, d)).at[:5].set(q_real)
    r_real = jax.random.uniform(jax.random.PRNGKey(1), (17, d), jnp.float64)
    r = jnp.zeros((nr, d)).at[:17].set(r_real)
    w = jnp.zeros((nr,)).at[:17].set(1.0)
    s = jnp.asarray([-2.0])
    (got,) = gauss_chunk(q, r, w, s, tr=tr)
    want = gauss_tile_ref(q_real, r_real, jnp.ones((17,)), s)
    np.testing.assert_allclose(np.asarray(got)[:5], np.asarray(want), rtol=1e-10)


@pytest.mark.parametrize("d", [2, 16])
def test_lowering_produces_stablehlo(d):
    lowered, (tq, tr, nr) = lower_gauss_chunk(d)
    assert nr % tr == 0
    text = str(lowered.compiler_ir("stablehlo"))
    assert "stablehlo" in text or "mhlo" in text or "func.func" in text
