"""AOT bridge: the HLO-text artifacts parse, carry the right entry
signature, and the manifest matches the lowered shapes."""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.aot import build, to_hlo_text  # noqa: E402
from compile.model import lower_gauss_chunk  # noqa: E402


def test_hlo_text_is_parseable_hlo(tmp_path):
    lowered, (tq, tr, nr) = lower_gauss_chunk(2)
    text = to_hlo_text(lowered)
    # HLO text module header + the tuple-returning ROOT the rust side
    # unwraps with to_tuple1
    assert text.startswith("HloModule"), text[:80]
    assert f"f64[{tq},2]" in text, "query tile shape missing"
    assert f"f64[{nr},2]" in text, "reference chunk shape missing"
    assert "ROOT" in text


def test_build_writes_manifest_and_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = build(out, dims=(2, 3))
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    for d in (2, 3):
        entry = on_disk["artifacts"][str(d)]
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path)
        assert entry["chunk_refs"] % entry["block_refs"] == 0
        with open(path) as f:
            assert f.read(9) == "HloModule"


@pytest.mark.parametrize("d", [7, 16])
def test_high_dim_artifacts_lower(d):
    lowered, (tq, tr, nr) = lower_gauss_chunk(d)
    text = to_hlo_text(lowered)
    assert f"f64[{tq},{d}]" in text
