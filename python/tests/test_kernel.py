"""L1 correctness: the Pallas tile kernel vs the pure-jnp oracle,
swept over shapes, dtypes, bandwidths and degenerate inputs with
hypothesis."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels.gauss_tile import gauss_tile, vmem_budget_blocks  # noqa: E402
from compile.kernels.ref import gauss_sum_ref, gauss_tile_ref  # noqa: E402


def make_case(seed, tq, nr, d, dtype):
    k = jax.random.PRNGKey(seed)
    kq, kr, kw = jax.random.split(k, 3)
    q = jax.random.uniform(kq, (tq, d), dtype)
    r = jax.random.uniform(kr, (nr, d), dtype)
    w = jax.random.uniform(kw, (nr,), dtype, minval=0.1, maxval=2.0)
    return q, r, w


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tq=st.sampled_from([1, 3, 8, 32]),
    blocks=st.integers(1, 4),
    tr=st.sampled_from([4, 16, 64]),
    d=st.sampled_from([1, 2, 3, 5, 7, 10, 16]),
    h=st.floats(1e-3, 1e3),
)
def test_kernel_matches_ref_f64(seed, tq, blocks, tr, d, h):
    q, r, w = make_case(seed, tq, blocks * tr, d, jnp.float64)
    s = jnp.asarray([-0.5 / (h * h)], jnp.float64)
    got = gauss_tile(q, r, w, s, tr=tr)
    want = gauss_tile_ref(q, r, w, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([2, 5]),
    h=st.floats(0.1, 1e2),
)
def test_kernel_matches_ref_f32(seed, d, h):
    # f32 note: the MXU-friendly ‖q‖²+‖r‖²−2q·rᵀ form loses ~1e-7 of
    # absolute precision to cancellation; exp amplifies that by 1/(2h²),
    # so at h ≪ 0.1 (on unit-cube data) f32 output error is inherent to
    # the rearrangement, not a bug. Production artifacts are f64; this
    # test pins the f32 contract in its valid regime.
    q, r, w = make_case(seed, 16, 64, d, jnp.float32)
    s = jnp.asarray([-0.5 / (h * h)], jnp.float32)
    got = gauss_tile(q, r, w, s, tr=32)
    want = gauss_tile_ref(q, r, w, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_zero_weights_contribute_nothing():
    q, r, w = make_case(0, 8, 64, 3, jnp.float64)
    w = w.at[32:].set(0.0)
    s = jnp.asarray([-0.5 / 0.25])
    got = gauss_tile(q, r, w, s, tr=16)
    want = gauss_tile_ref(q[:, :], r[:32], w[:32], s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_self_distance_gives_weight():
    # query == single reference → G = w exactly (exp(0) = 1)
    q = jnp.asarray([[0.3, 0.7]])
    r = jnp.tile(q, (8, 1))
    w = jnp.zeros((8,)).at[0].set(2.5)
    s = jnp.asarray([-2.0])
    got = gauss_tile(q, r, w, s, tr=8)
    np.testing.assert_allclose(np.asarray(got), [2.5], rtol=1e-14)


def test_huge_distance_underflows_to_zero():
    q = jnp.zeros((4, 2))
    r = jnp.full((16, 2), 1e6)
    w = jnp.ones((16,))
    s = jnp.asarray([-0.5])
    got = gauss_tile(q, r, w, s, tr=16)
    assert np.all(np.asarray(got) == 0.0)


def test_block_count_invariance():
    # same answer regardless of how the reference axis is blocked
    q, r, w = make_case(7, 8, 128, 4, jnp.float64)
    s = jnp.asarray([-8.0])
    outs = [np.asarray(gauss_tile(q, r, w, s, tr=tr)) for tr in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-12)


def test_bandwidth_form_wrapper():
    q, r, w = make_case(9, 4, 32, 2, jnp.float64)
    h = 0.37
    s = jnp.asarray([-0.5 / (h * h)])
    np.testing.assert_allclose(
        np.asarray(gauss_sum_ref(q, r, w, h)),
        np.asarray(gauss_tile_ref(q, r, w, s)),
        rtol=1e-14,
    )


@pytest.mark.parametrize("d", [1, 2, 3, 5, 7, 10, 16])
def test_vmem_budget_fits(d):
    tq, tr = vmem_budget_blocks(d)
    working = 8 * (tq * d + tr * d + tq * tr + tq)
    assert working * 4 <= 16 * 2**20, f"D={d}: {working} bytes won't double-buffer"
    assert tq >= 32 and tr >= 64
